//! The continuous serving loop: open-loop arrivals → admission queue →
//! batched JESA rounds → simulated-time completion accounting.
//!
//! The engine is a discrete-event simulation over *simulated* time (the
//! same clock as [`crate::protocol::sim`]): arrivals carry timestamps
//! from the traffic process, a round occupies the server for its
//! discrete-event latency, and per-query latency is
//! `completion − arrival` (queueing delay + L rounds of radio/compute).
//! Wall-clock time is tracked separately and only measures how fast the
//! engine itself runs.
//!
//! Round execution mirrors [`DmoeServer::serve_batch`] steps 3–5 at the
//! selection/energy level (cf. the Figs. 6–9 experiments): the Rayleigh
//! channel is refreshed once per round, each layer's joint problem is
//! solved through the [solution cache](crate::serve::cache) (or directly
//! when caching is off), energy is charged per eq. (3)/(4), and the
//! round's latency comes from [`simulate_round`]. The per-layer solves of
//! a round are independent (the synthetic workload fixes each layer's
//! gates up front), so they are dispatched across the in-tree
//! [`parallel_map`] thread pool.
//!
//! [`DmoeServer::serve_batch`]: crate::coordinator::DmoeServer::serve_batch

use super::cache::{
    quantize_round, CacheStats, ChannelSignature, QuantizerConfig, SolutionCache,
};
use super::queue::{AdmissionQueue, QueueConfig};
use super::traffic::{Arrival, TrafficConfig, TrafficGenerator};
use crate::channel::ChannelModel;
use crate::coordinator::ServePolicy;
use crate::energy::{EnergyBreakdown, EnergyLedger, EnergyModel};
use crate::gating::GateScores;
use crate::jesa::{solve_round, JesaOptions, RoundProblem, RoundSolution};
use crate::metrics::{Metrics, SelectionPattern};
use crate::protocol::{simulate_round, ComputeModel, RoundTimeline};
use crate::util::pool::{default_workers, parallel_map};
use crate::util::stats;
use crate::SystemConfig;
use std::sync::Mutex;
use std::time::Instant;

/// Engine configuration beyond the system/traffic configs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub policy: ServePolicy,
    pub queue: QueueConfig,
    /// Solution-cache entry capacity; 0 disables caching (rounds are then
    /// solved on the exact, unquantized channel).
    pub cache_capacity: usize,
    pub quant: QuantizerConfig,
    /// Worker threads for the per-layer solves of a round.
    pub workers: usize,
    /// Seed for the channel stream and the (fixed) JESA BCD
    /// initialization. Fixed per engine so identical cache keys denote
    /// identical solver inputs.
    pub seed: u64,
    /// Keep every round's [`RoundTimeline`]s in the report (tests /
    /// debugging only — memory grows with rounds × layers).
    pub record_timelines: bool,
}

impl ServeOptions {
    pub fn new(policy: ServePolicy, queue: QueueConfig) -> Self {
        Self {
            policy,
            queue,
            cache_capacity: 4096,
            quant: QuantizerConfig::default(),
            workers: default_workers(),
            seed: 0x5E4E_7E11,
            record_timelines: false,
        }
    }
}

/// One served query's lifecycle timestamps (simulated seconds).
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub domain: usize,
    pub arrival_s: f64,
    pub start_s: f64,
    pub done_s: f64,
}

impl Completion {
    /// End-to-end latency: queueing delay plus the round's L layers of
    /// radio + compute.
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.arrival_s
    }

    pub fn wait_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }
}

/// One executed round.
#[derive(Debug, Clone)]
pub struct RoundLog {
    pub start_s: f64,
    /// Sum of the L per-layer discrete-event round latencies.
    pub latency_s: f64,
    pub queries: usize,
    pub tokens: usize,
    pub cache_hits: usize,
}

/// Everything a serving run reports.
pub struct ServeReport {
    pub process: String,
    pub generated: usize,
    pub completed: usize,
    pub shed_queue_full: usize,
    pub shed_deadline: usize,
    pub rounds: usize,
    /// Simulated time of the last completion.
    pub sim_end_s: f64,
    /// Wall-clock engine runtime.
    pub wall_s: f64,
    pub tokens: u64,
    pub energy: EnergyBreakdown,
    pub cache: CacheStats,
    pub fallbacks: usize,
    pub completions: Vec<Completion>,
    pub rounds_log: Vec<RoundLog>,
    /// `timelines[round][layer]` — only with
    /// [`ServeOptions::record_timelines`].
    pub timelines: Vec<Vec<RoundTimeline>>,
    pub pattern: SelectionPattern,
    pub ledger: EnergyLedger,
    pub metrics: Metrics,
}

impl ServeReport {
    pub fn shed(&self) -> usize {
        self.shed_queue_full + self.shed_deadline
    }

    pub fn shed_rate(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.shed() as f64 / self.generated as f64
        }
    }

    /// Completed queries per simulated second.
    pub fn throughput_qps(&self) -> f64 {
        if self.sim_end_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.sim_end_s
        }
    }

    /// Completed queries per wall-clock second (engine speed).
    pub fn wall_throughput_qps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.wall_s
        }
    }

    fn latencies(&self) -> Vec<f64> {
        self.completions.iter().map(|c| c.latency_s()).collect()
    }

    pub fn latency_mean_s(&self) -> f64 {
        stats::mean(&self.latencies())
    }

    pub fn latency_p50_s(&self) -> f64 {
        stats::percentile(&self.latencies(), 50.0)
    }

    pub fn latency_p99_s(&self) -> f64 {
        stats::percentile(&self.latencies(), 99.0)
    }

    /// Human-readable summary (the `dmoe serve` output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "serve[{}]: {} generated, {} completed, {} shed ({:.2}% = {} queue-full + {} deadline)\n",
            self.process,
            self.generated,
            self.completed,
            self.shed(),
            self.shed_rate() * 100.0,
            self.shed_queue_full,
            self.shed_deadline,
        ));
        out.push_str(&format!(
            "rounds {} ({} tokens), sim time {:.2} s, wall {:.2} s ({:.0} q/s engine speed)\n",
            self.rounds,
            self.tokens,
            self.sim_end_s,
            self.wall_s,
            self.wall_throughput_qps(),
        ));
        out.push_str(&format!(
            "throughput {:.2} q/s (simulated)  latency p50 {:.3} s  p99 {:.3} s  mean {:.3} s\n",
            self.throughput_qps(),
            self.latency_p50_s(),
            self.latency_p99_s(),
            self.latency_mean_s(),
        ));
        out.push_str(&format!(
            "solution cache: {}/{} hits ({:.1}%), {} entries, {} evictions\n",
            self.cache.hits,
            self.cache.lookups(),
            self.cache.hit_rate() * 100.0,
            self.cache.entries,
            self.cache.evictions,
        ));
        out.push_str(&format!(
            "energy {:.4} J (comm {:.4} + comp {:.4}), fallbacks {}\n",
            self.energy.total_j(),
            self.energy.comm_j,
            self.energy.comp_j,
            self.fallbacks,
        ));
        out
    }
}

/// The continuous multi-user serving engine.
pub struct ServeEngine {
    cfg: SystemConfig,
    opts: ServeOptions,
    energy: EnergyModel,
    compute: ComputeModel,
}

impl ServeEngine {
    pub fn new(cfg: &SystemConfig, opts: ServeOptions) -> Self {
        let k = cfg.moe.experts;
        assert!(
            opts.policy.importance.layers() == cfg.moe.layers,
            "policy importance covers {} layers, system has {}",
            opts.policy.importance.layers(),
            cfg.moe.layers
        );
        assert!(
            opts.queue.batch_queries <= k,
            "batch of {} queries exceeds {k} expert nodes",
            opts.queue.batch_queries
        );
        if opts.cache_capacity > 0 {
            // Fail on degenerate --step / --gate-grid values up front
            // rather than producing silently-wrong canonical physics.
            opts.quant.validate();
        }
        Self {
            cfg: cfg.clone(),
            opts,
            energy: EnergyModel::new(cfg.channel.clone(), cfg.energy.clone()),
            compute: ComputeModel::ramp(cfg.moe.experts, 1e-3),
        }
    }

    /// Override the latency-simulation compute model (default: the
    /// paper's heterogeneous `a_j` ramp, as in the coordinator).
    pub fn set_compute_model(&mut self, model: ComputeModel) {
        assert_eq!(model.per_token_s.len(), self.cfg.moe.experts);
        self.compute = model;
    }

    pub fn options(&self) -> &ServeOptions {
        &self.opts
    }

    /// Run one open-loop serving simulation over a traffic stream.
    pub fn run(&self, traffic: &TrafficConfig) -> ServeReport {
        let t0 = Instant::now();
        let k = self.cfg.moe.experts;
        let layers = self.cfg.moe.layers;
        let generator = TrafficGenerator::new(traffic.clone(), k, layers);
        let arrivals = generator.generate();
        let generated = arrivals.len();

        let mut channel = ChannelModel::new(self.cfg.channel.clone(), k, self.opts.seed);
        let cache = Mutex::new(SolutionCache::new(self.opts.cache_capacity));
        let mut queue = AdmissionQueue::new(self.opts.queue.clone());
        let mut ledger = EnergyLedger::new(layers);
        let mut pattern = SelectionPattern::new(layers, k);
        let mut metrics = Metrics::new();
        let mut completions: Vec<Completion> = Vec::new();
        let mut rounds_log: Vec<RoundLog> = Vec::new();
        let mut timelines: Vec<Vec<RoundTimeline>> = Vec::new();
        let mut fallbacks = 0usize;
        let mut tokens_total = 0u64;
        let mut free_at = 0.0f64;

        let jesa_opts = JesaOptions {
            policy: self.opts.policy.policy,
            allocation: self.opts.policy.allocation,
            seed: self.opts.seed ^ 0x1E5A,
            ..JesaOptions::default()
        };

        let mut stream = arrivals.into_iter().peekable();
        while stream.peek().is_some() || !queue.is_empty() {
            if queue.is_empty() {
                queue.push(stream.next().expect("stream non-empty"));
                continue;
            }
            // Admit every arrival that lands before the next round could
            // start: the formation trigger, or later if the server is
            // still busy (so capacity shedding sees the real backlog).
            let trigger = queue.trigger_time_s().expect("queue non-empty");
            let start_if_now = trigger.max(free_at);
            if let Some(next) = stream.peek() {
                if next.at_s <= start_if_now {
                    queue.push(stream.next().expect("peeked"));
                    continue;
                }
            }
            // Form a round. A drained stream fires the partial batch as
            // soon as its newest member has arrived instead of idling out
            // the deadline trigger.
            let formed_at = if !queue.batch_ready() && stream.peek().is_none() {
                queue.newest_arrival_s().expect("queue non-empty")
            } else {
                trigger
            };
            let start = formed_at.max(free_at);
            queue.shed_expired(start);
            if queue.is_empty() {
                continue;
            }
            let batch = queue.take_batch();

            let t_round = Instant::now();
            let (latency_s, hits, round_fallbacks, round_timelines) = self.execute_round(
                &batch,
                &mut channel,
                &cache,
                &jesa_opts,
                &mut ledger,
                &mut pattern,
            );
            metrics.observe_s("round_wall", t_round.elapsed().as_secs_f64());
            metrics.inc("rounds", 1);
            metrics.inc("layer_solves", layers as u64);
            metrics.inc("cache_hits", hits as u64);
            fallbacks += round_fallbacks;
            let round_tokens: usize = batch.iter().map(|a| a.query.tokens).sum();
            tokens_total += (round_tokens * layers) as u64;

            free_at = start + latency_s;
            rounds_log.push(RoundLog {
                start_s: start,
                latency_s,
                queries: batch.len(),
                tokens: round_tokens,
                cache_hits: hits,
            });
            if let Some(tls) = round_timelines {
                timelines.push(tls);
            }
            for a in &batch {
                completions.push(Completion {
                    id: a.query.id,
                    domain: a.query.domain,
                    arrival_s: a.at_s,
                    start_s: start,
                    done_s: free_at,
                });
            }
        }

        let (shed_queue_full, shed_deadline) = queue.shed_counts();
        let sim_end_s = completions.iter().map(|c| c.done_s).fold(0.0, f64::max);
        let cache_stats = cache.lock().unwrap().stats();
        ServeReport {
            process: traffic.process.label().to_string(),
            generated,
            completed: completions.len(),
            shed_queue_full,
            shed_deadline,
            rounds: rounds_log.len(),
            sim_end_s,
            wall_s: t0.elapsed().as_secs_f64(),
            tokens: tokens_total,
            energy: ledger.total(),
            cache: cache_stats,
            fallbacks,
            completions,
            rounds_log,
            timelines,
            pattern,
            ledger,
            metrics,
        }
    }

    /// Execute one round: refresh the channel, solve each layer through
    /// the cache (in parallel), account energy/patterns, and return the
    /// round's discrete-event latency.
    #[allow(clippy::too_many_arguments)]
    fn execute_round(
        &self,
        batch: &[Arrival],
        channel: &mut ChannelModel,
        cache: &Mutex<SolutionCache>,
        jesa_opts: &JesaOptions,
        ledger: &mut EnergyLedger,
        pattern: &mut SelectionPattern,
    ) -> (f64, usize, usize, Option<Vec<RoundTimeline>>) {
        let k = self.cfg.moe.experts;
        let layers = self.cfg.moe.layers;
        let s0 = self.energy.energy.s0_bytes;
        let caching = self.opts.cache_capacity > 0;
        let policy = &self.opts.policy;

        // One Rayleigh realization per round; with caching on, all
        // accounting runs against the canonical (quantized) state so that
        // cache hits and misses produce identical physics.
        let state = channel.realize();
        let (solve_state, csig) = if caching {
            let sig = ChannelSignature::quantize(&state, self.opts.quant.log2_step);
            (sig.canonical_state(self.opts.quant.log2_step), Some(sig))
        } else {
            (state, None)
        };

        let layer_ids: Vec<usize> = (0..layers).collect();
        let workers = self.opts.workers.clamp(1, layers.max(1));
        let results: Vec<(RoundSolution, bool)> = parallel_map(&layer_ids, workers, |&l| {
            let mut gates: Vec<Vec<GateScores>> = vec![Vec::new(); k];
            for (src, a) in batch.iter().enumerate() {
                gates[src] = a.query.gates[l].clone();
            }
            let threshold = policy.z * policy.importance.gamma(l);
            match &csig {
                Some(sig) => {
                    let (key, problem) = quantize_round(
                        sig,
                        &self.opts.quant,
                        &gates,
                        threshold,
                        policy.max_active,
                        &self.energy,
                        jesa_opts,
                    );
                    if let Some(sol) = cache.lock().unwrap().get(&key) {
                        return (sol, true);
                    }
                    let sol = solve_round(&solve_state, &problem, &self.energy, jesa_opts);
                    cache.lock().unwrap().insert(key, sol.clone());
                    (sol, false)
                }
                None => {
                    let problem = RoundProblem {
                        gates,
                        threshold,
                        max_active: policy.max_active,
                    };
                    (solve_round(&solve_state, &problem, &self.energy, jesa_opts), false)
                }
            }
        });

        let round_tokens: usize = batch.iter().map(|a| a.query.tokens).sum();
        let mut latency_s = 0.0;
        let mut hits = 0usize;
        let mut fallbacks = 0usize;
        let mut tls = self.opts.record_timelines.then(Vec::new);
        for (l, (sol, hit)) in results.iter().enumerate() {
            let timeline = simulate_round(&solve_state, sol, &self.compute, s0);
            latency_s += timeline.round_latency_s;
            ledger.charge_comm(l, sol.energy.comm_j);
            ledger.charge_comp(l, sol.energy.comp_j);
            ledger.count_tokens(l, round_tokens as u64);
            for row in &sol.selections {
                for sel in row {
                    pattern.record(l, &sel.selected);
                }
            }
            fallbacks += sol.fallbacks;
            hits += *hit as usize;
            if let Some(v) = tls.as_mut() {
                v.push(timeline);
            }
        }
        (latency_s, hits, fallbacks, tls)
    }
}

/// Estimate the mean discrete-event latency of one full-batch round under
/// a config/policy/workload (no caching, exact channel): used by the CLI
/// to auto-derive an arrival rate targeting a utilization level, and by
/// benchmarks as a capacity probe.
pub fn estimate_round_latency_s(
    cfg: &SystemConfig,
    policy: &ServePolicy,
    traffic: &TrafficConfig,
    rounds: usize,
) -> f64 {
    assert!(rounds >= 1);
    let k = cfg.moe.experts;
    let queue = QueueConfig {
        capacity: rounds * k + k,
        batch_queries: k,
        max_wait_s: f64::INFINITY,
        deadline_s: f64::INFINITY,
    };
    let opts = ServeOptions {
        cache_capacity: 0,
        workers: 1,
        seed: traffic.seed ^ 0xCA11_B4A7E,
        ..ServeOptions::new(policy.clone(), queue)
    };
    let engine = ServeEngine::new(cfg, opts);
    // Saturating arrivals: every round is a full batch.
    let probe = TrafficConfig {
        process: super::traffic::ArrivalProcess::Poisson { rate_qps: 1e9 },
        queries: rounds * k,
        ..traffic.clone()
    };
    let report = engine.run(&probe);
    let latencies: Vec<f64> = report.rounds_log.iter().map(|r| r.latency_s).collect();
    stats::mean(&latencies)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_setup() -> (SystemConfig, ServeOptions, TrafficConfig) {
        let mut cfg = SystemConfig::tiny(); // K=3, L=2, M=12
        cfg.workload.seed = 99;
        let policy = ServePolicy::jesa(0.8, 2, cfg.moe.layers);
        let queue = QueueConfig::for_system(cfg.moe.experts, 1.0);
        let opts = ServeOptions {
            workers: 1,
            ..ServeOptions::new(policy, queue)
        };
        let traffic = TrafficConfig {
            queries: 300,
            // Few domains + noise-free templates: round keys repeat, so
            // the cache-hit assertions below are statistically safe.
            domains: 4,
            tokens_per_query: 2,
            seed: 7,
            ..TrafficConfig::poisson(10.0, 300)
        };
        (cfg, opts, traffic)
    }

    #[test]
    fn conserves_queries_and_orders_time() {
        let (cfg, opts, traffic) = tiny_setup();
        let engine = ServeEngine::new(&cfg, opts);
        let report = engine.run(&traffic);
        assert_eq!(report.generated, 300);
        assert_eq!(report.completed + report.shed(), report.generated);
        assert!(report.rounds > 0);
        for c in &report.completions {
            assert!(c.start_s >= c.arrival_s - 1e-12, "started before arrival");
            assert!(c.done_s > c.start_s, "round must take time");
        }
        // Rounds never overlap: the server is serial.
        for w in report.rounds_log.windows(2) {
            assert!(
                w[1].start_s >= w[0].start_s + w[0].latency_s - 1e-12,
                "rounds overlap"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (cfg, opts, traffic) = tiny_setup();
        let a = ServeEngine::new(&cfg, opts.clone()).run(&traffic);
        let b = ServeEngine::new(&cfg, opts).run(&traffic);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.shed(), b.shed());
        assert_eq!(a.energy.total_j().to_bits(), b.energy.total_j().to_bits());
        assert_eq!(a.cache.hits, b.cache.hits);
        for (x, y) in a.completions.iter().zip(b.completions.iter()) {
            assert_eq!(x.done_s.to_bits(), y.done_s.to_bits());
        }
    }

    #[test]
    fn template_workload_hits_the_cache() {
        let (cfg, opts, traffic) = tiny_setup();
        let engine = ServeEngine::new(&cfg, opts);
        let report = engine.run(&traffic);
        assert!(
            report.cache.hits > 0,
            "noise-free domain templates must repeat: {:?}",
            report.cache
        );
        assert!(report.cache.hit_rate() > 0.0);
    }

    #[test]
    fn cacheless_run_reports_zero_hit_rate() {
        let (cfg, mut opts, traffic) = tiny_setup();
        opts.cache_capacity = 0;
        let report = ServeEngine::new(&cfg, opts).run(&traffic);
        assert_eq!(report.cache.hits, 0);
        assert_eq!(report.cache.entries, 0);
        assert_eq!(report.completed + report.shed(), report.generated);
    }

    #[test]
    fn overload_sheds_by_deadline() {
        let (cfg, mut opts, mut traffic) = tiny_setup();
        // A deadline far below the round latency forces shedding.
        opts.queue.deadline_s = 1e-6;
        opts.queue.max_wait_s = 1e-7;
        traffic.process = super::super::traffic::ArrivalProcess::Poisson { rate_qps: 1000.0 };
        let report = ServeEngine::new(&cfg, opts).run(&traffic);
        assert!(report.shed() > 0, "overload must shed");
        assert_eq!(report.completed + report.shed(), report.generated);
    }

    #[test]
    fn capacity_estimate_is_positive_and_finite() {
        let (cfg, opts, traffic) = tiny_setup();
        let lr = estimate_round_latency_s(&cfg, &opts.policy, &traffic, 3);
        assert!(lr.is_finite() && lr > 0.0, "round latency {lr}");
    }
}

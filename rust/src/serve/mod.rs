//! `serve` — the continuous multi-user serving engine.
//!
//! The paper's protocol schedules one round of expert inference at a
//! time; this subsystem wraps that round machinery in an open-loop
//! serving pipeline, the layer every scaling extension (sharding, async
//! backends, multi-server) plugs into:
//!
//! ```text
//!  traffic ──► admission queue ──► batch former ──► round executor ──► report
//!  (Poisson /   (bounded FIFO,     (size/deadline    (channel refresh,
//!   MMPP /       QoS shedding)      triggers)         cached JESA solve,
//!   diurnal)                                          DES timeline)
//! ```
//!
//! * [`traffic`] — arrival processes (Poisson, bursty MMPP, diurnal) over
//!   a synthetic multi-domain query stream with per-domain gate
//!   templates.
//! * [`queue`] — bounded admission queue with capacity- and
//!   deadline-based shedding and trigger-based batch formation.
//! * [`cache`] — the JESA/DES solution cache: rounds are solved on a
//!   quantized canonical problem and memoized, so repeated
//!   channel/traffic regimes skip branch-and-bound entirely; cache hits
//!   are bit-identical to fresh solves by construction.
//! * [`engine`] — the discrete-event serving loop tying it together and
//!   reporting throughput, p50/p99 simulated latency, shed rate, cache
//!   hit rate, and energy through [`crate::metrics`].
//!
//! The engine runs at the selection/energy level on synthetic gate
//! scores (like the paper-scale Figs. 6–9 experiments), so it needs no
//! compiled model artifacts; `dmoe serve` exercises it from the CLI.
//!
//! Callers normally reach this engine through the
//! [scenario front door](crate::scenario): it implements the
//! [`Engine`](crate::scenario::Engine) facade trait, streams
//! round/shed/cache events to any
//! [`EngineObserver`](crate::scenario::EngineObserver)
//! ([`ServeEngine::run_streaming`]), and its report carries a
//! determinism digest ([`ServeReport::digest`]). The capacity estimator
//! ([`estimate_round_latency_s`]) is shared with the fleet — a
//! `path_scale` argument derates it for mobility-attenuated cells.
//!
//! # Fleet: lanes and the router
//!
//! One `ServeEngine` is a single serving *lane*: one admission queue, one
//! channel, one round executor. The [`fleet`](crate::fleet) subsystem
//! scales this out by running N lanes ("cells") side by side behind a
//! user-facing router:
//!
//! ```text
//!               ┌► cell 0: queue ─► rounds ─► report ┐
//!  traffic ─► router                                 ├─► fleet report
//!   (users)    └► cell N: queue ─► rounds ─► report ┘
//!                 ▲ shared sharded SolutionCache (cross-cell hits)
//! ```
//!
//! The fleet's cells execute on a [work-stealing
//! executor](crate::util::executor) (lane-parallel, report bit-identical
//! to the interleaved loop), while *within* a round the per-layer solves
//! keep using the [`parallel_map`](crate::util::pool::parallel_map)
//! pool — see the [fleet concurrency model](crate::fleet) for the full
//! contract. The pieces this module contributes to that layout:
//!
//! * [`SharedSolutionCache`] — the thread-safe (`Arc` + per-shard lock)
//!   cache handle every lane shares; hits are attributed per lane and
//!   cross-lane reuse is counted ([`CacheStats::cross_hits`]). A lane
//!   with a private handle behaves exactly like the single-engine cache.
//! * [`ShardedSolutionCache`] — the memo table split N ways by key hash
//!   with per-shard locks, so concurrent lanes stop serializing on one
//!   mutex; hits stay bit-identical to the unsharded cache (routing is a
//!   pure, deterministic function of the key) and all stats aggregate
//!   commutatively.
//! * [`EvictionPolicy`] — LRU or cost-aware (greedy-dual) eviction; the
//!   latter keeps expensive branch-and-bound solutions resident longer
//!   than cheap greedy ones.
//! * [`derive_quantizer`] / [`ServeOptions::adapt_quant`] — workload-
//!   adaptive quantization grids derived from observed channel/gate
//!   variance during warmup; the fleet derives one shared grid so all
//!   cells' cache keys stay compatible.
//! * [`ServeEngine::run_with_cache`] — the multi-lane entry point; the
//!   fleet's cells run the same round pipeline through
//!   `engine::execute_round`.

pub mod cache;
pub mod engine;
pub mod queue;
pub mod traffic;

pub use cache::{
    quantize_round, solve_quantized, CacheStats, EvictionPolicy, QuantizerConfig,
    SharedSolutionCache, ShardedSolutionCache, SolutionCache,
};
pub use engine::{
    derive_quantizer, estimate_round_latency_s, ServeEngine, ServeOptions, ServeReport,
};
pub use queue::{AdmissionQueue, QueueConfig, ShedReason};
pub use traffic::{Arrival, ArrivalProcess, SyntheticQuery, TrafficConfig, TrafficGenerator};

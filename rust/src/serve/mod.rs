//! `serve` — the continuous multi-user serving engine.
//!
//! The paper's protocol schedules one round of expert inference at a
//! time; this subsystem wraps that round machinery in an open-loop
//! serving pipeline, the layer every scaling extension (sharding, async
//! backends, multi-server) plugs into:
//!
//! ```text
//!  traffic ──► admission queue ──► batch former ──► round executor ──► report
//!  (Poisson /   (bounded FIFO,     (size/deadline    (channel refresh,
//!   MMPP /       QoS shedding)      triggers)         cached JESA solve,
//!   diurnal)                                          DES timeline)
//! ```
//!
//! * [`traffic`] — arrival processes (Poisson, bursty MMPP, diurnal) over
//!   a synthetic multi-domain query stream with per-domain gate
//!   templates.
//! * [`queue`] — bounded admission queue with capacity- and
//!   deadline-based shedding and trigger-based batch formation.
//! * [`cache`] — the JESA/DES solution cache: rounds are solved on a
//!   quantized canonical problem and memoized, so repeated
//!   channel/traffic regimes skip branch-and-bound entirely; cache hits
//!   are bit-identical to fresh solves by construction.
//! * [`engine`] — the discrete-event serving loop tying it together and
//!   reporting throughput, p50/p99 simulated latency, shed rate, cache
//!   hit rate, and energy through [`crate::metrics`].
//!
//! The engine runs at the selection/energy level on synthetic gate
//! scores (like the paper-scale Figs. 6–9 experiments), so it needs no
//! compiled model artifacts; `dmoe serve` exercises it from the CLI.

pub mod cache;
pub mod engine;
pub mod queue;
pub mod traffic;

pub use cache::{quantize_round, solve_quantized, CacheStats, QuantizerConfig, SolutionCache};
pub use engine::{estimate_round_latency_s, ServeEngine, ServeOptions, ServeReport};
pub use queue::{AdmissionQueue, QueueConfig, ShedReason};
pub use traffic::{Arrival, ArrivalProcess, SyntheticQuery, TrafficConfig, TrafficGenerator};

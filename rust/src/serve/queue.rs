//! Admission control: a bounded FIFO of pending arrivals, shed policies,
//! and the batch former that groups arrivals into protocol rounds.
//!
//! Two shed paths implement QoS-aware load shedding under overload:
//!
//! * **Capacity** — the queue holds at most `capacity` pending queries;
//!   an arrival finding it full is shed immediately (the radio front-end
//!   has nowhere to park it).
//! * **Deadline** — when a round is about to start, any pending query
//!   that has already waited longer than `deadline_s` is shed instead of
//!   served: its QoS is unrecoverable, and serving it would only push the
//!   queries behind it past their own deadlines (the classic
//!   overload-collapse failure this policy prevents).
//!
//! Batch formation is trigger-based, mirroring production batchers: a
//! round forms as soon as `batch_queries` arrivals are pending
//! (size trigger) or the oldest pending query has waited `max_wait_s`
//! (deadline trigger, bounding tail latency at low load). The
//! [engine](crate::serve::engine) owns the clock and drives these
//! mechanics.

use super::traffic::Arrival;
use std::collections::VecDeque;

/// Why a query was shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The admission queue was full on arrival.
    QueueFull,
    /// The query exceeded its waiting-time deadline before a round could
    /// take it.
    DeadlineExceeded,
}

/// Queue / batch-former configuration.
#[derive(Debug, Clone)]
pub struct QueueConfig {
    /// Maximum pending queries before arrivals are shed.
    pub capacity: usize,
    /// Size trigger: form a round once this many queries are pending.
    /// Must not exceed the system's expert count `K` (one query per
    /// source expert per round).
    pub batch_queries: usize,
    /// Deadline trigger: form a (partial) round once the oldest pending
    /// query has waited this long.
    pub max_wait_s: f64,
    /// QoS deadline on queue waiting time; pending queries older than
    /// this at round start are shed.
    pub deadline_s: f64,
}

impl QueueConfig {
    /// Defaults for a K-expert system with round latency ≈ `round_s`:
    /// full batches, a batch-formation wait of one round, a deadline of
    /// eight rounds, and room for ~four full batches in the queue.
    pub fn for_system(k: usize, round_s: f64) -> Self {
        assert!(k >= 1 && round_s > 0.0);
        Self {
            capacity: (4 * k).max(16),
            batch_queries: k,
            max_wait_s: round_s,
            deadline_s: 8.0 * round_s,
        }
    }

    fn validate(&self) {
        assert!(self.batch_queries >= 1, "batch_queries must be >= 1");
        assert!(
            self.capacity >= self.batch_queries,
            "capacity {} cannot hold one batch of {}",
            self.capacity,
            self.batch_queries
        );
        assert!(self.max_wait_s >= 0.0 && self.deadline_s >= 0.0);
    }
}

/// Bounded FIFO admission queue with shed accounting.
#[derive(Debug, Clone)]
pub struct AdmissionQueue {
    cfg: QueueConfig,
    pending: VecDeque<Arrival>,
    shed_full: usize,
    shed_deadline: usize,
    /// Every shed query's id with the reason it was dropped.
    shed_log: Vec<(u64, ShedReason)>,
}

impl AdmissionQueue {
    pub fn new(cfg: QueueConfig) -> Self {
        cfg.validate();
        Self {
            cfg,
            pending: VecDeque::new(),
            shed_full: 0,
            shed_deadline: 0,
            shed_log: Vec::new(),
        }
    }

    pub fn config(&self) -> &QueueConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Queries shed so far, by reason: `(queue_full, deadline)`.
    pub fn shed_counts(&self) -> (usize, usize) {
        (self.shed_full, self.shed_deadline)
    }

    /// Per-query shed record: `(query id, reason)`, in shed order.
    pub fn shed_log(&self) -> &[(u64, ShedReason)] {
        &self.shed_log
    }

    /// Admit an arrival. Returns `false` (and records the shed) when the
    /// queue is full.
    pub fn push(&mut self, arrival: Arrival) -> bool {
        if self.pending.len() >= self.cfg.capacity {
            self.shed_full += 1;
            self.shed_log.push((arrival.query.id, ShedReason::QueueFull));
            return false;
        }
        debug_assert!(
            self.pending
                .back()
                .map(|b| b.at_s <= arrival.at_s)
                .unwrap_or(true),
            "arrivals must be admitted in time order"
        );
        self.pending.push_back(arrival);
        true
    }

    /// Arrival time of the oldest pending query.
    pub fn oldest_arrival_s(&self) -> Option<f64> {
        self.pending.front().map(|a| a.at_s)
    }

    /// Arrival time of the newest pending query.
    pub fn newest_arrival_s(&self) -> Option<f64> {
        self.pending.back().map(|a| a.at_s)
    }

    /// Arrival time of the `i`-th oldest pending query (0-based).
    pub fn kth_arrival_s(&self, i: usize) -> Option<f64> {
        self.pending.get(i).map(|a| a.at_s)
    }

    /// True once the size trigger is met.
    pub fn batch_ready(&self) -> bool {
        self.pending.len() >= self.cfg.batch_queries
    }

    /// The time at which the queue's formation trigger fires, given no
    /// further arrivals: the size trigger fires retroactively when the
    /// batch-completing query arrived; otherwise the deadline trigger
    /// fires `max_wait_s` after the oldest arrival. `None` when empty.
    pub fn trigger_time_s(&self) -> Option<f64> {
        if self.batch_ready() {
            self.kth_arrival_s(self.cfg.batch_queries - 1)
        } else {
            self.oldest_arrival_s().map(|t| t + self.cfg.max_wait_s)
        }
    }

    /// Shed every pending query whose waiting time at `start_s` exceeds
    /// the QoS deadline; returns how many were shed.
    pub fn shed_expired(&mut self, start_s: f64) -> usize {
        let before = self.pending.len();
        let deadline = self.cfg.deadline_s;
        let drained = std::mem::take(&mut self.pending);
        for a in drained {
            if start_s - a.at_s <= deadline {
                self.pending.push_back(a);
            } else {
                self.shed_log.push((a.query.id, ShedReason::DeadlineExceeded));
            }
        }
        let shed = before - self.pending.len();
        self.shed_deadline += shed;
        shed
    }

    /// Take up to `batch_queries` queries, FIFO.
    pub fn take_batch(&mut self) -> Vec<Arrival> {
        let n = self.cfg.batch_queries.min(self.pending.len());
        self.pending.drain(..n).collect()
    }

    /// Drain every pending query (a crashed cell loses its queue all at
    /// once; the fleet re-routes the orphans). Shed accounting is
    /// untouched — the orphans are not lost yet.
    pub fn take_all(&mut self) -> Vec<Arrival> {
        self.pending.drain(..).collect()
    }

    /// Admit a query re-routed from a crashed cell. Unlike [`push`],
    /// the arrival may be older than this queue's tail (it was admitted
    /// elsewhere first), so it is inserted in time order to keep the
    /// FIFO invariant; a full queue sheds it as `QueueFull` just like a
    /// fresh arrival, so re-routed queries never vanish. Returns `false`
    /// on shed.
    ///
    /// [`push`]: AdmissionQueue::push
    pub fn push_rerouted(&mut self, arrival: Arrival) -> bool {
        if self.pending.len() >= self.cfg.capacity {
            self.shed_full += 1;
            self.shed_log.push((arrival.query.id, ShedReason::QueueFull));
            return false;
        }
        let pos = self
            .pending
            .iter()
            .position(|p| p.at_s > arrival.at_s)
            .unwrap_or(self.pending.len());
        self.pending.insert(pos, arrival);
        true
    }

    /// Record an externally-decided shed: a crash orphan whose re-route
    /// found no accepting cell still has to land in exactly one queue's
    /// accounting (conservation — re-routed queries never vanish).
    pub fn shed_forced(&mut self, id: u64) {
        self.shed_full += 1;
        self.shed_log.push((id, ShedReason::QueueFull));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::traffic::SyntheticQuery;

    fn arrival(id: u64, at_s: f64) -> Arrival {
        Arrival {
            at_s,
            query: SyntheticQuery {
                id,
                domain: 0,
                tokens: 1,
                gates: Vec::new(),
            },
        }
    }

    fn queue(capacity: usize, batch: usize, max_wait: f64, deadline: f64) -> AdmissionQueue {
        AdmissionQueue::new(QueueConfig {
            capacity,
            batch_queries: batch,
            max_wait_s: max_wait,
            deadline_s: deadline,
        })
    }

    #[test]
    fn fifo_batches() {
        let mut q = queue(8, 3, 1.0, 10.0);
        for i in 0..5 {
            assert!(q.push(arrival(i, i as f64 * 0.1)));
        }
        assert!(q.batch_ready());
        let batch = q.take_batch();
        assert_eq!(batch.iter().map(|a| a.query.id).collect::<Vec<_>>(), [0, 1, 2]);
        assert_eq!(q.len(), 2);
        assert!(!q.batch_ready());
    }

    #[test]
    fn capacity_sheds_on_push() {
        let mut q = queue(2, 2, 1.0, 10.0);
        assert!(q.push(arrival(0, 0.0)));
        assert!(q.push(arrival(1, 0.1)));
        assert!(!q.push(arrival(2, 0.2)));
        assert_eq!(q.shed_counts(), (1, 0));
        assert_eq!(q.shed_log(), &[(2, ShedReason::QueueFull)]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn deadline_sheds_expired_only() {
        let mut q = queue(8, 4, 1.0, 2.0);
        q.push(arrival(0, 0.0));
        q.push(arrival(1, 1.5));
        q.push(arrival(2, 2.9));
        // At t = 3.0: query 0 waited 3.0 > 2.0 → shed; 1 and 2 stay.
        assert_eq!(q.shed_expired(3.0), 1);
        assert_eq!(q.shed_counts(), (0, 1));
        assert_eq!(q.shed_log(), &[(0, ShedReason::DeadlineExceeded)]);
        assert_eq!(q.oldest_arrival_s(), Some(1.5));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn trigger_times() {
        let mut q = queue(8, 2, 0.5, 10.0);
        assert_eq!(q.trigger_time_s(), None);
        q.push(arrival(0, 1.0));
        // Partial queue: deadline trigger at oldest + max_wait.
        assert_eq!(q.trigger_time_s(), Some(1.5));
        q.push(arrival(1, 1.2));
        // Size trigger: fires when the batch-completing query arrived.
        assert_eq!(q.trigger_time_s(), Some(1.2));
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn rejects_capacity_below_batch() {
        queue(1, 2, 1.0, 1.0);
    }

    #[test]
    fn take_all_drains_without_shedding() {
        let mut q = queue(8, 3, 1.0, 10.0);
        for i in 0..4 {
            q.push(arrival(i, i as f64 * 0.1));
        }
        let orphans = q.take_all();
        assert_eq!(orphans.iter().map(|a| a.query.id).collect::<Vec<_>>(), [0, 1, 2, 3]);
        assert!(q.is_empty());
        assert_eq!(q.shed_counts(), (0, 0));
    }

    #[test]
    fn rerouted_arrivals_insert_in_time_order_and_shed_when_full() {
        let mut q = queue(3, 2, 1.0, 10.0);
        q.push(arrival(10, 1.0));
        q.push(arrival(11, 2.0));
        // An orphan older than the tail lands between existing entries.
        assert!(q.push_rerouted(arrival(5, 1.5)));
        assert_eq!(q.oldest_arrival_s(), Some(1.0));
        assert_eq!(q.kth_arrival_s(1), Some(1.5));
        // The queue is now full: the next orphan sheds as QueueFull.
        assert!(!q.push_rerouted(arrival(6, 0.5)));
        assert_eq!(q.shed_counts(), (1, 0));
        assert_eq!(q.shed_log(), &[(6, ShedReason::QueueFull)]);
    }
}

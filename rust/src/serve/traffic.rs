//! Open-loop traffic generation: arrival processes and the synthetic
//! multi-domain query stream they carry.
//!
//! Three arrival processes cover the serving regimes the engine is
//! stress-tested under (cf. the channel-aware-gating line of work —
//! selection quality must hold under diverse, time-varying traffic, not a
//! single static batch):
//!
//! * [`ArrivalProcess::Poisson`] — memoryless baseline at a fixed rate.
//! * [`ArrivalProcess::Mmpp`] — a 2-state Markov-modulated Poisson
//!   process (bursty: exponential dwell times alternate a low and a high
//!   rate), the classic model for flash-crowd traffic.
//! * [`ArrivalProcess::Diurnal`] — a non-homogeneous Poisson process with
//!   a sinusoidal rate (day/night load curve), sampled by thinning.
//!
//! Each arrival carries a [`SyntheticQuery`]: a domain drawn from a Zipf
//! mixture and per-layer gate-score vectors built from a fixed per-domain
//! *template* plus optional multiplicative noise. Queries of the same
//! domain therefore have near-identical gate signatures — the
//! similarity structure (cf. SiftMoE) that the serve-side
//! [solution cache](crate::serve::cache) exploits.

use crate::gating::{GateScores, SyntheticGate};
use crate::util::rng::Xoshiro256pp;

/// The arrival process shaping inter-arrival times.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals at `rate_qps` queries/second.
    Poisson { rate_qps: f64 },
    /// 2-state Markov-modulated Poisson process: the rate alternates
    /// between `low_qps` and `high_qps`, dwelling in each state for an
    /// exponential time with mean `mean_dwell_s`.
    Mmpp {
        low_qps: f64,
        high_qps: f64,
        mean_dwell_s: f64,
    },
    /// Sinusoidal-rate Poisson process: `λ(t) = mean·(1 + a·sin(2πt/T))`
    /// with the amplitude `a` derived from the peak-to-trough ratio.
    Diurnal {
        mean_qps: f64,
        /// Peak rate divided by trough rate (≥ 1).
        peak_to_trough: f64,
        period_s: f64,
    },
}

impl ArrivalProcess {
    /// The canonical bursty stream: a 2-state MMPP swinging between
    /// 0.25× and 1.75× the mean rate (so the long-run mean equals
    /// `mean_qps`) with the given dwell time. One definition shared by
    /// the CLI, examples and benches.
    pub fn bursty_around(mean_qps: f64, mean_dwell_s: f64) -> Self {
        ArrivalProcess::Mmpp {
            low_qps: mean_qps * 0.25,
            high_qps: mean_qps * 1.75,
            mean_dwell_s,
        }
    }

    /// The canonical diurnal stream around a mean rate.
    pub fn diurnal_around(mean_qps: f64, peak_to_trough: f64, period_s: f64) -> Self {
        ArrivalProcess::Diurnal {
            mean_qps,
            peak_to_trough,
            period_s,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Mmpp { .. } => "bursty(mmpp)",
            ArrivalProcess::Diurnal { .. } => "diurnal",
        }
    }

    /// Long-run mean arrival rate (queries/second).
    pub fn mean_qps(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate_qps } => *rate_qps,
            // Equal mean dwell in both states → time is split evenly.
            ArrivalProcess::Mmpp { low_qps, high_qps, .. } => 0.5 * (low_qps + high_qps),
            ArrivalProcess::Diurnal { mean_qps, .. } => *mean_qps,
        }
    }

    fn validate(&self) {
        match self {
            ArrivalProcess::Poisson { rate_qps } => {
                assert!(*rate_qps > 0.0, "poisson rate must be > 0");
            }
            ArrivalProcess::Mmpp {
                low_qps,
                high_qps,
                mean_dwell_s,
            } => {
                assert!(*low_qps > 0.0 && *high_qps > 0.0, "mmpp rates must be > 0");
                assert!(*mean_dwell_s > 0.0, "mmpp dwell must be > 0");
            }
            ArrivalProcess::Diurnal {
                mean_qps,
                peak_to_trough,
                period_s,
            } => {
                assert!(*mean_qps > 0.0, "diurnal mean rate must be > 0");
                assert!(*peak_to_trough >= 1.0, "peak_to_trough must be >= 1");
                assert!(*period_s > 0.0, "diurnal period must be > 0");
            }
        }
    }

    /// Draw `n` arrival timestamps (strictly increasing, seconds from 0).
    fn arrival_times(&self, n: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
        self.validate();
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Poisson { rate_qps } => {
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exponential(rate_qps);
                    out.push(t);
                }
            }
            ArrivalProcess::Mmpp {
                low_qps,
                high_qps,
                mean_dwell_s,
            } => {
                let mut t = 0.0;
                let mut high = false;
                let mut next_switch = rng.exponential(1.0 / mean_dwell_s);
                while out.len() < n {
                    let rate = if high { high_qps } else { low_qps };
                    let dt = rng.exponential(rate);
                    if t + dt >= next_switch {
                        // State flips before the candidate arrival; the
                        // exponential is memoryless, so redraw from the
                        // switch instant at the new state's rate.
                        t = next_switch;
                        high = !high;
                        next_switch = t + rng.exponential(1.0 / mean_dwell_s);
                        continue;
                    }
                    t += dt;
                    out.push(t);
                }
            }
            ArrivalProcess::Diurnal {
                mean_qps,
                peak_to_trough,
                period_s,
            } => {
                // Thinning (Lewis–Shedler): propose at the peak rate,
                // accept with probability λ(t)/λ_max.
                let amp = (peak_to_trough - 1.0) / (peak_to_trough + 1.0);
                let rate_max = mean_qps * (1.0 + amp);
                let mut t = 0.0;
                while out.len() < n {
                    t += rng.exponential(rate_max);
                    let rate_t = mean_qps
                        * (1.0 + amp * (2.0 * std::f64::consts::PI * t / period_s).sin());
                    if rng.next_f64() * rate_max < rate_t {
                        out.push(t);
                    }
                }
            }
        }
        out
    }
}

/// One synthetic user query: a domain, a token count, and pre-generated
/// per-layer gate scores (the serving engine runs at the selection /
/// energy level, like the paper-scale Figs. 6–9 experiments — no trained
/// gate network of this width exists).
#[derive(Debug, Clone)]
pub struct SyntheticQuery {
    pub id: u64,
    pub domain: usize,
    /// Number of tokens (hidden states) the query contributes per round.
    pub tokens: usize,
    /// `gates[l][t]` — gate scores for token `t` at layer `l`.
    pub gates: Vec<Vec<GateScores>>,
}

/// A timestamped arrival.
#[derive(Debug, Clone)]
pub struct Arrival {
    pub at_s: f64,
    pub query: SyntheticQuery,
}

/// Traffic-stream configuration.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    pub process: ArrivalProcess,
    /// Total queries to generate.
    pub queries: usize,
    /// Number of query domains; drawn from a Zipf(1) mixture
    /// (`P(d) ∝ 1/(d+1)`), so low-index domains dominate.
    pub domains: usize,
    pub tokens_per_query: usize,
    /// Dirichlet concentration of the per-domain gate templates.
    pub gate_concentration: f64,
    /// Multiplicative gate bias toward a domain's home expert.
    pub domain_bias: f64,
    /// Per-query multiplicative log-normal gate noise around the domain
    /// template (0 = every query of a domain shares the template exactly).
    pub gate_noise: f64,
    pub seed: u64,
}

impl TrafficConfig {
    /// Poisson stream with the defaults the CLI uses.
    pub fn poisson(rate_qps: f64, queries: usize) -> Self {
        Self {
            process: ArrivalProcess::Poisson { rate_qps },
            queries,
            domains: 8,
            tokens_per_query: 4,
            gate_concentration: 2.0,
            domain_bias: 4.0,
            gate_noise: 0.0,
            seed: 0xD_0E,
        }
    }
}

/// Generates a reproducible arrival stream for a (K experts, L layers)
/// system. Domain gate templates are fixed at construction; every call to
/// [`TrafficGenerator::generate`] yields the same stream.
#[derive(Debug, Clone)]
pub struct TrafficGenerator {
    cfg: TrafficConfig,
    experts: usize,
    layers: usize,
    /// `templates[d][l]` — the domain's characteristic gate vector.
    templates: Vec<Vec<GateScores>>,
    /// Zipf mixture weights over domains.
    weights: Vec<f64>,
}

impl TrafficGenerator {
    pub fn new(cfg: TrafficConfig, experts: usize, layers: usize) -> Self {
        assert!(experts >= 1 && layers >= 1);
        assert!(cfg.domains >= 1, "need at least one domain");
        assert!(cfg.queries >= 1, "need at least one query");
        assert!(cfg.tokens_per_query >= 1, "queries must carry tokens");
        assert!(cfg.gate_noise >= 0.0);
        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed ^ 0x7AF1_C0DE_7E3A_0001);
        let templates = (0..cfg.domains)
            .map(|d| {
                let mut bias = vec![1.0; experts];
                bias[d % experts] *= cfg.domain_bias.max(1.0);
                let gate = SyntheticGate::new(experts, cfg.gate_concentration).with_bias(bias);
                (0..layers).map(|_| gate.sample(&mut rng)).collect()
            })
            .collect();
        let weights = (0..cfg.domains).map(|d| 1.0 / (d + 1) as f64).collect();
        Self {
            cfg,
            experts,
            layers,
            templates,
            weights,
        }
    }

    pub fn config(&self) -> &TrafficConfig {
        &self.cfg
    }

    /// Expert count the gate templates were drawn for.
    pub fn experts(&self) -> usize {
        self.experts
    }

    /// Layer count each query carries gates for.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// The fixed gate template of a domain at a layer.
    pub fn template(&self, domain: usize, layer: usize) -> &GateScores {
        &self.templates[domain][layer]
    }

    /// Produce the full arrival stream (sorted by time).
    pub fn generate(&self) -> Vec<Arrival> {
        let mut rng = Xoshiro256pp::seed_from_u64(self.cfg.seed ^ 0x5EED_7FA1_C0DE_0001);
        let times = self.cfg.process.arrival_times(self.cfg.queries, &mut rng);
        times
            .into_iter()
            .enumerate()
            .map(|(i, at_s)| {
                let domain = rng.weighted_index(&self.weights);
                let gates = (0..self.layers)
                    .map(|l| {
                        (0..self.cfg.tokens_per_query)
                            .map(|_| self.perturbed(domain, l, &mut rng))
                            .collect()
                    })
                    .collect();
                Arrival {
                    at_s,
                    query: SyntheticQuery {
                        id: i as u64,
                        domain,
                        tokens: self.cfg.tokens_per_query,
                        gates,
                    },
                }
            })
            .collect()
    }

    fn perturbed(&self, domain: usize, layer: usize, rng: &mut Xoshiro256pp) -> GateScores {
        let template = &self.templates[domain][layer];
        if self.cfg.gate_noise == 0.0 {
            return template.clone();
        }
        let raw: Vec<f64> = template
            .as_slice()
            .iter()
            .map(|&s| s * (self.cfg.gate_noise * rng.normal()).exp())
            .collect();
        GateScores::new(raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(process: ArrivalProcess, queries: usize) -> TrafficGenerator {
        let cfg = TrafficConfig {
            process,
            queries,
            ..TrafficConfig::poisson(1.0, 1)
        };
        TrafficGenerator::new(cfg, 4, 3)
    }

    #[test]
    fn poisson_mean_interarrival() {
        let g = gen(ArrivalProcess::Poisson { rate_qps: 50.0 }, 20_000);
        let arrivals = g.generate();
        assert_eq!(arrivals.len(), 20_000);
        let span = arrivals.last().unwrap().at_s;
        let rate = arrivals.len() as f64 / span;
        assert!((rate - 50.0).abs() < 2.0, "empirical rate {rate}");
        for w in arrivals.windows(2) {
            assert!(w[1].at_s > w[0].at_s, "arrivals must be increasing");
        }
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // Index of dispersion of counts: ≈1 for Poisson, >1 for MMPP.
        let dispersion = |times: &[f64], window: f64| {
            let end = times.last().copied().unwrap_or(0.0);
            let bins = (end / window).ceil() as usize;
            let mut counts = vec![0.0f64; bins.max(1)];
            for &t in times {
                let b = ((t / window) as usize).min(counts.len() - 1);
                counts[b] += 1.0;
            }
            let mean = crate::util::stats::mean(&counts);
            let sd = crate::util::stats::stddev(&counts);
            sd * sd / mean.max(1e-9)
        };
        let p: Vec<f64> = gen(ArrivalProcess::Poisson { rate_qps: 40.0 }, 10_000)
            .generate()
            .iter()
            .map(|a| a.at_s)
            .collect();
        let m: Vec<f64> = gen(
            ArrivalProcess::Mmpp {
                low_qps: 8.0,
                high_qps: 72.0,
                mean_dwell_s: 2.0,
            },
            10_000,
        )
        .generate()
        .iter()
        .map(|a| a.at_s)
        .collect();
        let dp = dispersion(&p, 1.0);
        let dm = dispersion(&m, 1.0);
        assert!(dm > dp * 2.0, "mmpp dispersion {dm} vs poisson {dp}");
    }

    #[test]
    fn diurnal_rate_oscillates() {
        let period = 20.0;
        let g = gen(
            ArrivalProcess::Diurnal {
                mean_qps: 100.0,
                peak_to_trough: 4.0,
                period_s: period,
            },
            40_000,
        );
        let times: Vec<f64> = g.generate().iter().map(|a| a.at_s).collect();
        // Count arrivals in the rising half vs the falling half of each
        // period: sin > 0 on [0, T/2), < 0 on [T/2, T).
        let (mut peak, mut trough) = (0usize, 0usize);
        for &t in &times {
            if (t % period) < period / 2.0 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > trough as f64 * 1.5,
            "peak half {peak} vs trough half {trough}"
        );
    }

    #[test]
    fn domains_follow_zipf_and_templates_are_stable() {
        let g = gen(ArrivalProcess::Poisson { rate_qps: 10.0 }, 4000);
        let arrivals = g.generate();
        let mut counts = vec![0usize; g.config().domains];
        for a in &arrivals {
            counts[a.query.domain] += 1;
        }
        assert!(counts[0] > counts[g.config().domains - 1]);
        // gate_noise = 0 → every query of a domain carries the template.
        let a = arrivals
            .iter()
            .find(|a| a.query.domain == 0)
            .expect("domain 0 appears");
        for (l, row) in a.query.gates.iter().enumerate() {
            for gs in row {
                assert_eq!(gs.as_slice(), g.template(0, l).as_slice());
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let g = gen(ArrivalProcess::Poisson { rate_qps: 10.0 }, 100);
        let a = g.generate();
        let b = g.generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
            assert_eq!(x.query.domain, y.query.domain);
        }
    }

    #[test]
    fn noise_perturbs_but_preserves_normalization() {
        let mut cfg = TrafficConfig::poisson(10.0, 50);
        cfg.gate_noise = 0.2;
        let g = TrafficGenerator::new(cfg, 4, 2);
        for a in g.generate() {
            for row in &a.query.gates {
                for gs in row {
                    let sum: f64 = gs.as_slice().iter().sum();
                    assert!((sum - 1.0).abs() < 1e-9);
                }
            }
        }
    }
}

//! Regression checking: diff a fresh sweep manifest against a
//! committed baseline, and deep-verify a sweep root on disk.
//!
//! The contract has two tiers:
//!
//! * **Bit-exact** — per-point `scenario_digest` and `report_digest`.
//!   A scenario-digest difference means the grid itself changed
//!   (different spec, preset drift): verdict **CHANGED**. The same
//!   scenario producing a different report digest means engine
//!   behavior drifted: verdict **REGRESSED**.
//! * **Tolerance-banded** — informational perf fields that legal
//!   implementation changes may move: cache hit rate within
//!   [`HIT_RATE_TOL`] absolute, solver nodes expanded within
//!   [`NODES_REL_TOL`] relative once past the [`NODES_ABS_FLOOR`]
//!   absolute floor. Out-of-band drift is **REGRESSED**. Wall-clock
//!   fields are never checked.

use crate::sweep::spec::{SweepSpec, SWEEP_SCHEMA_VERSION};
use crate::telemetry::artifact::{checksum, verify_artifact};
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

/// Maximum absolute cache-hit-rate drift before a point regresses.
pub const HIT_RATE_TOL: f64 = 0.15;
/// Maximum relative solver-nodes drift before a point regresses …
pub const NODES_REL_TOL: f64 = 0.35;
/// … provided the absolute difference also exceeds this floor (tiny
/// sweeps expand few nodes; a handful of extra nodes is not a signal).
pub const NODES_ABS_FLOOR: f64 = 128.0;

/// Per-point verdict, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verdict {
    /// Digests bit-identical, informational fields in band.
    Pass,
    /// The scenario grid itself differs from the baseline.
    Changed,
    /// Same scenario, different behavior (or out-of-band perf drift).
    Regressed,
}

impl Verdict {
    pub fn label(&self) -> &'static str {
        match self {
            Verdict::Pass => "PASS",
            Verdict::Changed => "CHANGED",
            Verdict::Regressed => "REGRESSED",
        }
    }
}

/// One checked point.
#[derive(Debug, Clone)]
pub struct PointCheck {
    pub name: String,
    pub verdict: Verdict,
    pub detail: String,
}

/// The full per-point diff of a fresh sweep against a baseline.
#[derive(Debug, Clone)]
pub struct CheckReport {
    pub points: Vec<PointCheck>,
}

impl CheckReport {
    /// The most severe verdict across all points (PASS when empty).
    pub fn worst(&self) -> Verdict {
        self.points
            .iter()
            .map(|p| p.verdict)
            .max()
            .unwrap_or(Verdict::Pass)
    }

    /// One aligned line per point: `name  VERDICT  detail`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for p in &self.points {
            out.push_str(&format!("{:<6} {:<10} {}\n", p.name, p.verdict.label(), p.detail));
        }
        out
    }
}

/// Diff two sweep manifests point-by-point (matched by point name).
/// Pure on the manifests — no filesystem access.
pub fn check_manifests(baseline: &Json, fresh: &Json) -> CheckReport {
    let empty: &[Json] = &[];
    let bpoints = baseline.get("points").as_arr().unwrap_or(empty);
    let fpoints = fresh.get("points").as_arr().unwrap_or(empty);
    let bmap: BTreeMap<&str, &Json> = bpoints
        .iter()
        .filter_map(|p| p.get("name").as_str().map(|n| (n, p)))
        .collect();
    let mut points = Vec::new();
    for fp in fpoints {
        let name = fp.get("name").as_str().unwrap_or("?").to_string();
        match bmap.get(name.as_str()) {
            Some(bp) => points.push(check_point(&name, bp, fp)),
            None => points.push(PointCheck {
                name,
                verdict: Verdict::Changed,
                detail: "point absent from baseline (grid changed)".to_string(),
            }),
        }
    }
    for bp in bpoints {
        let name = bp.get("name").as_str().unwrap_or("?");
        if !fpoints
            .iter()
            .any(|fp| fp.get("name").as_str() == Some(name))
        {
            points.push(PointCheck {
                name: name.to_string(),
                verdict: Verdict::Changed,
                detail: "point missing from fresh sweep (grid changed)".to_string(),
            });
        }
    }
    CheckReport { points }
}

fn check_point(name: &str, baseline: &Json, fresh: &Json) -> PointCheck {
    let bs = baseline.get("scenario_digest").as_str().unwrap_or("");
    let fs_ = fresh.get("scenario_digest").as_str().unwrap_or("");
    if bs != fs_ {
        return PointCheck {
            name: name.to_string(),
            verdict: Verdict::Changed,
            detail: format!("scenario digest {fs_} differs from baseline {bs}"),
        };
    }
    let br = baseline.get("report_digest").as_str().unwrap_or("");
    let fr = fresh.get("report_digest").as_str().unwrap_or("");
    if br != fr {
        return PointCheck {
            name: name.to_string(),
            verdict: Verdict::Regressed,
            detail: format!(
                "report digest {fr} differs from baseline {br} (same scenario digest {bs})"
            ),
        };
    }
    let bh = baseline
        .get("informational")
        .get("cache_hit_rate")
        .as_f64()
        .unwrap_or(0.0);
    let fh = fresh
        .get("informational")
        .get("cache_hit_rate")
        .as_f64()
        .unwrap_or(0.0);
    if (bh - fh).abs() > HIT_RATE_TOL {
        return PointCheck {
            name: name.to_string(),
            verdict: Verdict::Regressed,
            detail: format!(
                "cache hit rate {fh:.3} vs baseline {bh:.3} exceeds ±{HIT_RATE_TOL} band"
            ),
        };
    }
    let bn = baseline
        .get("informational")
        .get("solver_nodes")
        .as_f64()
        .unwrap_or(0.0);
    let fnodes = fresh
        .get("informational")
        .get("solver_nodes")
        .as_f64()
        .unwrap_or(0.0);
    let diff = (bn - fnodes).abs();
    if diff > NODES_ABS_FLOOR && diff > NODES_REL_TOL * bn.max(1.0) {
        return PointCheck {
            name: name.to_string(),
            verdict: Verdict::Regressed,
            detail: format!(
                "solver nodes {fnodes:.0} vs baseline {bn:.0} exceeds \
                 {:.0}% band (floor {NODES_ABS_FLOOR:.0})",
                100.0 * NODES_REL_TOL
            ),
        };
    }
    PointCheck {
        name: name.to_string(),
        verdict: Verdict::Pass,
        detail: format!("digests {bs} / {br}"),
    }
}

/// Deep-verify a sweep root on disk: schema version, the canonical
/// spec checksum, and every per-point artifact (re-checksummed via
/// [`verify_artifact`]) cross-checked against the sweep manifest's
/// digests. Returns `(points_verified, sweep_name)`.
pub fn verify_sweep_root(dir: &Path) -> Result<(usize, String)> {
    let manifest_text =
        fs::read_to_string(dir.join("manifest.json")).context("read manifest.json")?;
    let manifest = Json::parse(&manifest_text).context("manifest.json")?;
    let version = manifest.get("sweep_schema_version").as_f64();
    crate::ensure!(
        version == Some(SWEEP_SCHEMA_VERSION as f64),
        "unsupported sweep schema version {version:?} (this build reads {SWEEP_SCHEMA_VERSION})"
    );
    let name = manifest
        .get("name")
        .as_str()
        .unwrap_or("sweep")
        .to_string();

    let spec_text = fs::read_to_string(dir.join("spec.json")).context("read spec.json")?;
    let spec = SweepSpec::from_json_str(&spec_text).context("spec.json")?;
    let got = checksum(spec.to_json().to_string_pretty().as_bytes());
    let want = manifest.get("spec_fnv1a").as_str().unwrap_or("");
    crate::ensure!(
        got == want,
        "spec.json: canonical checksum mismatch ({got} recomputed, manifest says {want})"
    );

    let points = manifest
        .get("points")
        .as_arr()
        .context("manifest points section missing")?;
    crate::ensure!(!points.is_empty(), "sweep manifest lists no points");
    for p in points {
        let pname = p.get("name").as_str().unwrap_or("?");
        let pdir = p
            .get("dir")
            .as_str()
            .with_context(|| format!("point {pname}: manifest entry missing 'dir'"))?;
        let (sd, rd) = verify_artifact(&dir.join(pdir))
            .with_context(|| format!("sweep point {pname} ({pdir})"))?;
        let want_sd = p.get("scenario_digest").as_str().unwrap_or("");
        let want_rd = p.get("report_digest").as_str().unwrap_or("");
        crate::ensure!(
            sd == want_sd,
            "{pdir}/manifest.json: scenario digest {sd} disagrees with sweep manifest {want_sd}"
        );
        crate::ensure!(
            rd == want_rd,
            "{pdir}/manifest.json: report digest {rd} disagrees with sweep manifest {want_rd}"
        );
    }
    Ok((points.len(), name))
}

//! Cross-run comparison: pivot the sweep manifest's per-point metrics
//! into `comparison.json` and an aligned-column stdout table.

use crate::sweep::spec::SWEEP_SCHEMA_VERSION;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::table::Table;
use std::fs;
use std::path::Path;

/// The comparison document derived from a sweep manifest: the same
/// per-point rows, re-keyed for consumers that only want the pivot
/// (axes + metrics + informational fields), plus provenance.
pub fn comparison_json(manifest: &Json) -> Json {
    Json::obj(vec![
        (
            "sweep_schema_version",
            Json::Num(SWEEP_SCHEMA_VERSION as f64),
        ),
        ("name", manifest.get("name").clone()),
        ("git_rev", manifest.get("git_rev").clone()),
        ("spec_fnv1a", manifest.get("spec_fnv1a").clone()),
        ("points", manifest.get("points").clone()),
    ])
}

/// Write `comparison.json` under the sweep root.
pub fn write_comparison(root: &Path, manifest: &Json) -> Result<Json> {
    let doc = comparison_json(manifest);
    fs::write(root.join("comparison.json"), doc.to_string_pretty())
        .context("write comparison.json")?;
    Ok(doc)
}

/// Render the manifest as an aligned-column table: one row per point,
/// one column per swept axis, then the pivot metrics. `hit%` and
/// `nodes` are informational (excluded from the bit-identity
/// contract; see `sweep::check` for their tolerance bands).
pub fn render_table(manifest: &Json) -> String {
    let empty: &[Json] = &[];
    let points = manifest.get("points").as_arr().unwrap_or(empty);
    let axes: Vec<String> = points
        .first()
        .map(|p| {
            p.get("labels")
                .as_arr()
                .unwrap_or(empty)
                .iter()
                .map(|l| l.at(0).as_str().unwrap_or("?").to_string())
                .collect()
        })
        .unwrap_or_default();

    let mut header: Vec<&str> = vec!["point"];
    header.extend(axes.iter().map(|s| s.as_str()));
    header.extend_from_slice(&[
        "p50_s", "p95_s", "p99_s", "shed%", "J/query", "hit%", "nodes",
    ]);
    let name = manifest.get("name").as_str().unwrap_or("sweep");
    let mut table = Table::new(&header).with_title(&format!(
        "sweep {name} ({} points, git {})",
        points.len(),
        manifest.get("git_rev").as_str().unwrap_or("unknown")
    ));
    for p in points {
        let metrics = p.get("metrics");
        let info = p.get("informational");
        let labels = p.get("labels").as_arr().unwrap_or(empty);
        let mut row = vec![p.get("name").as_str().unwrap_or("?").to_string()];
        for i in 0..axes.len() {
            row.push(
                labels
                    .get(i)
                    .map(|l| l.at(1).as_str().unwrap_or("?"))
                    .unwrap_or("?")
                    .to_string(),
            );
        }
        row.push(Table::num(metrics.get("p50_s").as_f64(), 4));
        row.push(Table::num(metrics.get("p95_s").as_f64(), 4));
        row.push(Table::num(metrics.get("p99_s").as_f64(), 4));
        row.push(Table::num(
            metrics.get("shed_rate").as_f64().map(|x| 100.0 * x),
            2,
        ));
        row.push(Table::num(metrics.get("energy_per_query_j").as_f64(), 4));
        row.push(Table::num(
            info.get("cache_hit_rate").as_f64().map(|x| 100.0 * x),
            1,
        ));
        row.push(Table::num(info.get("solver_nodes").as_f64(), 0));
        table.row(row);
    }
    table.render()
}

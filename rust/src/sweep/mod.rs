//! Scenario sweeps: declarative grids of scenario variations, executed
//! in parallel, indexed by a checksummed sweep manifest, compared
//! across points, and regression-diffed against committed baselines.
//!
//! The layer has four pieces, one per submodule:
//!
//! * [`spec`] — the schema-versioned [`SweepSpec`] document: a base
//!   [`Scenario`](crate::scenario::Scenario) (preset name or inline
//!   object) plus axes over cells, chaos, autoscale, selector, traffic
//!   process/rate, the importance factor γ₀, and seed, expanded
//!   deterministically to a named point grid.
//! * [`runner`] — [`run_sweep`]: fans the grid out on the
//!   work-stealing executor ([`util::executor`](crate::util::executor),
//!   one lane per point), writes one PR-6 run artifact per point plus
//!   a sweep-level `manifest.json` with per-point scenario/report
//!   digests, FNV checksums, and the git rev.
//! * [`compare`] — `comparison.json` + the aligned-column stdout
//!   table pivoting p50/p95/p99 latency, shed rate, energy/query,
//!   cache hit rate, and solver nodes across the axes.
//! * [`check`] — `dmoe sweep --check`: per-point
//!   PASS/CHANGED/REGRESSED verdicts (bit-exact on digests,
//!   tolerance-banded on informational perf fields) and the deep
//!   on-disk verifier behind `dmoe artifact <sweep-root>`.
//!
//! Everything is driven by `dmoe sweep` (see `main.rs`) and gated in
//! `ci.sh` against the committed `baselines/sweep-tier1/` grid; the
//! full format and tolerance bands are documented in MONITORING.md.

pub mod check;
pub mod compare;
pub mod runner;
pub mod spec;

pub use check::{
    check_manifests, verify_sweep_root, CheckReport, PointCheck, Verdict, HIT_RATE_TOL,
    NODES_ABS_FLOOR, NODES_REL_TOL,
};
pub use compare::{comparison_json, render_table, write_comparison};
pub use runner::run_sweep;
pub use spec::{Axes, BaseRef, SweepPoint, SweepSpec, SWEEP_SCHEMA_VERSION};

//! Sweep execution: fan a point grid out on the work-stealing
//! executor, write one PR-6 run artifact per point, and index the
//! whole sweep in a sweep-level `manifest.json`.
//!
//! Layout under the sweep root:
//!
//! ```text
//! ROOT/
//!   spec.json        # canonical SweepSpec (written only if missing)
//!   manifest.json    # the sweep index (see below)
//!   comparison.json  # written by the CLI via sweep::compare
//!   points/p000/     # a full run artifact (manifest/scenario/report/
//!   points/p001/     #   telemetry JSON) per grid point
//!   ...
//! ```
//!
//! Determinism contract: per-point `scenario_digest` / `report_digest`
//! and the deterministic `metrics` block are bit-identical across runs
//! of the same spec; `unix_time_s`, `git_rev`, and every field under
//! a point's `informational` object (wall clock, cache hit split under
//! lane parallelism, solver node counts) are exempt.

use crate::scenario::{self, PrepareOptions, RunReport};
use crate::sweep::spec::{SweepPoint, SweepSpec, SWEEP_SCHEMA_VERSION};
use crate::telemetry::artifact::{git_rev, write_run_artifact};
use crate::telemetry::TelemetryObserver;
use crate::util::error::{Context, Result};
use crate::util::executor::{Executor, Task};
use crate::util::json::Json;
use std::fs;
use std::path::Path;
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// Expand `spec`, run every point (`workers`-wide across points), and
/// write artifacts plus the sweep manifest under `root`. Returns the
/// manifest that was written.
pub fn run_sweep(spec: &SweepSpec, root: &Path, workers: usize) -> Result<Json> {
    let points = spec.expand()?;
    fs::create_dir_all(root.join("points"))
        .with_context(|| format!("sweep root {}", root.display()))?;
    let spec_path = root.join("spec.json");
    if !spec_path.exists() {
        // Never rewrite an existing spec (e.g. a hand-committed
        // baseline spec): the manifest's `spec_fnv1a` hashes the
        // canonical serialization, not the on-disk bytes.
        fs::write(&spec_path, spec.to_json().to_string_pretty()).context("write spec.json")?;
    }

    let slots: Vec<Mutex<Option<Result<Json>>>> =
        points.iter().map(|_| Mutex::new(None)).collect();
    let executor = Executor::new(workers.max(1));
    executor.scope(|scope| {
        let tasks: Vec<Task<'_>> = points
            .iter()
            .zip(slots.iter())
            .map(|(point, slot)| {
                Box::new(move || {
                    let entry = run_point(point, root);
                    *slot.lock().unwrap() = Some(entry);
                }) as Task<'_>
            })
            .collect();
        scope.run_batch(tasks);
    });

    let mut entries = Vec::with_capacity(points.len());
    for (point, slot) in points.iter().zip(slots.iter()) {
        let entry = slot
            .lock()
            .unwrap()
            .take()
            .expect("executor runs every sweep point task");
        entries.push(entry.with_context(|| format!("sweep point {}", point.name))?);
    }

    let unix_time_s = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let manifest = Json::obj(vec![
        (
            "sweep_schema_version",
            Json::Num(SWEEP_SCHEMA_VERSION as f64),
        ),
        ("name", Json::Str(spec.name.clone())),
        ("git_rev", Json::Str(git_rev())),
        ("unix_time_s", Json::Num(unix_time_s as f64)),
        ("spec_fnv1a", Json::Str(spec.digest())),
        ("points", Json::Arr(entries)),
    ]);
    fs::write(root.join("manifest.json"), manifest.to_string_pretty())
        .context("write sweep manifest.json")?;
    Ok(manifest)
}

/// Run one grid point and write its run artifact under
/// `ROOT/points/{name}/`. Returns the point's manifest entry.
fn run_point(point: &SweepPoint, root: &Path) -> Result<Json> {
    let dir = root.join("points").join(&point.name);
    let prepared = scenario::prepare_opts(&point.scenario, &PrepareOptions::default())?;
    let mut telemetry = TelemetryObserver::new();
    telemetry.set_layers(point.scenario.system.moe.layers);
    let report = prepared.run_observed(&mut telemetry);
    let manifest = write_run_artifact(&dir, &prepared.scenario, &report, &telemetry)?;
    let scenario_digest = manifest
        .get("scenario_digest")
        .as_str()
        .unwrap_or("")
        .to_string();
    let report_digest = manifest
        .get("report_digest")
        .as_str()
        .unwrap_or("")
        .to_string();
    Ok(point_entry(point, &report, scenario_digest, report_digest))
}

fn point_entry(
    point: &SweepPoint,
    report: &RunReport,
    scenario_digest: String,
    report_digest: String,
) -> Json {
    let labels = Json::Arr(
        point
            .labels
            .iter()
            .map(|(k, v)| Json::Arr(vec![Json::Str(k.clone()), Json::Str(v.clone())]))
            .collect(),
    );
    let completed = report.completed();
    let generated = report.generated();
    let shed_rate = if generated > 0 {
        report.shed() as f64 / generated as f64
    } else {
        0.0
    };
    let energy_per_query_j = if completed > 0 {
        report.energy().total_j() / completed as f64
    } else {
        0.0
    };
    Json::obj(vec![
        ("index", Json::Num(point.index as f64)),
        ("name", Json::Str(point.name.clone())),
        ("dir", Json::Str(format!("points/{}", point.name))),
        ("labels", labels),
        ("scenario_digest", Json::Str(scenario_digest)),
        ("report_digest", Json::Str(report_digest)),
        (
            "metrics",
            Json::obj(vec![
                ("p50_s", Json::Num(report.latency().p50_s())),
                ("p95_s", Json::Num(report.latency().p95_s())),
                ("p99_s", Json::Num(report.latency().p99_s())),
                ("shed_rate", Json::Num(shed_rate)),
                ("energy_per_query_j", Json::Num(energy_per_query_j)),
                ("generated", Json::Num(generated as f64)),
                ("completed", Json::Num(completed as f64)),
                ("rounds", Json::Num(report.rounds() as f64)),
            ]),
        ),
        (
            "informational",
            Json::obj(vec![
                ("wall_s", Json::Num(report.wall_s())),
                ("cache_hit_rate", Json::Num(report.cache().hit_rate())),
                ("solver_nodes", Json::Num(report.solver_nodes() as f64)),
            ]),
        ),
    ])
}

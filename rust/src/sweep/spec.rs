//! Declarative sweep specification: a base [`Scenario`] plus axes.
//!
//! A [`SweepSpec`] is the grid analogue of a `Scenario`: one
//! schema-versioned JSON document naming a base scenario (a preset name
//! or an inline scenario object) and up to eight axes — `cells`, the
//! failure-injection `chaos` section, the elastic-fleet `autoscale`
//! section, `selector`, traffic `process` / `rate`, the importance
//! factor `gamma0`, and `seed`.
//! [`SweepSpec::expand`] takes the cartesian
//! product in a fixed nesting order (cells outermost, seed innermost)
//! and yields one fully-validated [`SweepPoint`] scenario per grid
//! cell, named `p000`, `p001`, … in expansion order. Expansion is pure:
//! the same spec always produces the same points in the same order,
//! which is what lets a sweep manifest be regression-diffed
//! bit-for-bit (see [`crate::sweep::check`]).

use crate::chaos::ChaosSpec;
use crate::fleet::AutoscaleSpec;
use crate::scenario::{PolicyKind, ProcessSpec, RateSpec, Scenario};
use crate::selection::SelectorSpec;
use crate::util::error::{Context, Error, Result};
use crate::util::json::Json;

/// Sweep document schema version written to / accepted from JSON.
pub const SWEEP_SCHEMA_VERSION: u32 = 1;

/// The base scenario a sweep varies: a named preset or an inline spec.
#[derive(Debug, Clone, PartialEq)]
pub enum BaseRef {
    /// A name resolved through [`Scenario::preset`].
    Preset(String),
    /// A full inline scenario object.
    Inline(Box<Scenario>),
}

/// The grid axes. An empty axis means "inherit the base value" and
/// contributes a single slot to the product (it never multiplies the
/// grid and never emits a label).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Axes {
    /// Fleet sizes; `1` collapses the point to the single-cell serve
    /// engine (`fleet: null`), larger values shape a fleet.
    pub cells: Vec<usize>,
    /// Failure-injection sections ([`ChaosSpec`]); each value replaces
    /// the base scenario's `chaos` section wholesale.
    pub chaos: Vec<ChaosSpec>,
    /// Elastic-fleet control loops ([`AutoscaleSpec`]); each value
    /// replaces the base fleet's `autoscale` section wholesale.
    /// Requires a fleet-shaped base (or a `cells` axis value > 1).
    pub autoscale: Vec<AutoscaleSpec>,
    /// Selector registry names (`des`, `topk:K`, …).
    pub selector: Vec<SelectorSpec>,
    /// Traffic arrival processes.
    pub process: Vec<ProcessSpec>,
    /// Offered-rate specs (`{"utilization": u}` / `{"qps": q}`).
    pub rate: Vec<RateSpec>,
    /// Importance factor γ₀ values; requires a `jesa` or `lower-bound`
    /// base policy.
    pub gamma0: Vec<f64>,
    /// Workload seeds.
    pub seed: Vec<u64>,
}

impl Axes {
    const KEYS: &'static [&'static str] = &[
        "autoscale", "cells", "chaos", "gamma0", "process", "rate", "seed", "selector",
    ];

    /// True when no axis has any values (the grid is the bare base).
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
            && self.chaos.is_empty()
            && self.autoscale.is_empty()
            && self.selector.is_empty()
            && self.process.is_empty()
            && self.rate.is_empty()
            && self.gamma0.is_empty()
            && self.seed.is_empty()
    }
}

/// A serializable, schema-versioned description of a scenario grid.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    pub schema_version: u32,
    /// Sweep name; point scenarios are named `{name}-p{index:03}`.
    pub name: String,
    pub base: BaseRef,
    /// Override `traffic.queries` on every point (sweeps usually want
    /// far fewer queries than the base preset).
    pub queries: Option<usize>,
    /// Override the per-layer worker pool width on every point.
    pub workers: Option<usize>,
    /// Override `fleet.lane_workers` on every fleet-shaped point
    /// (`0` forces sequential lanes — bit-exact informational fields).
    pub lane_workers: Option<usize>,
    pub axes: Axes,
}

/// One expanded grid point: a validated scenario plus its coordinates.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    /// Position in expansion order (0-based).
    pub index: usize,
    /// `p{index:03}` — also the artifact subdirectory name.
    pub name: String,
    /// Ordered `(axis, value)` coordinate labels, one per non-empty
    /// axis, in the fixed nesting order.
    pub labels: Vec<(String, String)>,
    pub scenario: Scenario,
}

fn bad(path: &str, what: impl std::fmt::Display) -> Error {
    Error::msg(format!("{path}: {what}"))
}

fn check_keys(v: &Json, allowed: &[&str], path: &str) -> Result<()> {
    let obj = v
        .as_obj()
        .ok_or_else(|| bad(path, "expected a JSON object"))?;
    for key in obj.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(bad(
                path,
                format!("unknown key '{key}' (allowed: {})", allowed.join(", ")),
            ));
        }
    }
    Ok(())
}

fn opt_usize(v: &Json, key: &str, path: &str) -> Result<Option<usize>> {
    match v.get(key) {
        Json::Null => Ok(None),
        x => x
            .as_usize()
            .map(Some)
            .ok_or_else(|| bad(path, format!("'{key}' must be a non-negative integer"))),
    }
}

fn get_arr<'a>(v: &'a Json, key: &str, path: &str) -> Result<Option<&'a [Json]>> {
    match v.get(key) {
        Json::Null => Ok(None),
        x => x
            .as_arr()
            .map(Some)
            .ok_or_else(|| bad(path, format!("'{key}' must be an array"))),
    }
}

fn seed_from_json(x: &Json, path: &str) -> Result<u64> {
    let n = x
        .as_f64()
        .ok_or_else(|| bad(path, "seed must be a number"))?;
    if !(n >= 0.0 && n.fract() == 0.0 && n <= 9_007_199_254_740_992.0) {
        return Err(bad(
            path,
            format!("seed must be an f64-exact integer in [0, 2^53], got {n}"),
        ));
    }
    Ok(n as u64)
}

fn rate_label(r: &RateSpec) -> String {
    match r {
        RateSpec::Utilization(u) => format!("util:{u}"),
        RateSpec::Qps(q) => format!("qps:{q}"),
    }
}

/// Empty axis → one "inherit" slot; otherwise one slot per value.
fn slots<T: Clone>(xs: &[T]) -> Vec<Option<T>> {
    if xs.is_empty() {
        vec![None]
    } else {
        xs.iter().cloned().map(Some).collect()
    }
}

impl SweepSpec {
    const KEYS: &'static [&'static str] = &[
        "axes",
        "base",
        "lane_workers",
        "name",
        "queries",
        "sweep_schema_version",
        "workers",
    ];

    /// A spec over a named preset with no axes (a 1-point grid).
    pub fn new(name: &str, base_preset: &str) -> SweepSpec {
        SweepSpec {
            schema_version: SWEEP_SCHEMA_VERSION,
            name: name.to_string(),
            base: BaseRef::Preset(base_preset.to_string()),
            queries: None,
            workers: None,
            lane_workers: None,
            axes: Axes::default(),
        }
    }

    /// Canonical JSON form; [`Self::from_json`] round-trips it
    /// bit-identically through [`Json::to_string_pretty`].
    pub fn to_json(&self) -> Json {
        let mut axes: Vec<(&str, Json)> = Vec::new();
        if !self.axes.cells.is_empty() {
            axes.push((
                "cells",
                Json::Arr(self.axes.cells.iter().map(|&c| Json::Num(c as f64)).collect()),
            ));
        }
        if !self.axes.chaos.is_empty() {
            axes.push((
                "chaos",
                Json::Arr(self.axes.chaos.iter().map(|c| c.to_json()).collect()),
            ));
        }
        if !self.axes.autoscale.is_empty() {
            axes.push((
                "autoscale",
                Json::Arr(self.axes.autoscale.iter().map(|a| a.to_json()).collect()),
            ));
        }
        if !self.axes.selector.is_empty() {
            axes.push((
                "selector",
                Json::Arr(
                    self.axes
                        .selector
                        .iter()
                        .map(|s| Json::Str(s.name()))
                        .collect(),
                ),
            ));
        }
        if !self.axes.process.is_empty() {
            axes.push((
                "process",
                Json::Arr(self.axes.process.iter().map(|p| p.to_json()).collect()),
            ));
        }
        if !self.axes.rate.is_empty() {
            axes.push((
                "rate",
                Json::Arr(self.axes.rate.iter().map(|r| r.to_json()).collect()),
            ));
        }
        if !self.axes.gamma0.is_empty() {
            axes.push(("gamma0", Json::arr_f64(&self.axes.gamma0)));
        }
        if !self.axes.seed.is_empty() {
            axes.push((
                "seed",
                Json::Arr(self.axes.seed.iter().map(|&s| Json::Num(s as f64)).collect()),
            ));
        }
        let mut fields: Vec<(&str, Json)> = vec![
            (
                "sweep_schema_version",
                Json::Num(self.schema_version as f64),
            ),
            ("name", Json::Str(self.name.clone())),
            (
                "base",
                match &self.base {
                    BaseRef::Preset(p) => Json::Str(p.clone()),
                    BaseRef::Inline(s) => s.to_json(),
                },
            ),
            ("axes", Json::obj(axes)),
        ];
        if let Some(q) = self.queries {
            fields.push(("queries", Json::Num(q as f64)));
        }
        if let Some(w) = self.workers {
            fields.push(("workers", Json::Num(w as f64)));
        }
        if let Some(lw) = self.lane_workers {
            fields.push(("lane_workers", Json::Num(lw as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<SweepSpec> {
        check_keys(v, Self::KEYS, "sweep")?;
        let schema_version = match v.get("sweep_schema_version") {
            Json::Null => SWEEP_SCHEMA_VERSION as usize,
            x => x.as_usize().ok_or_else(|| {
                bad("sweep", "'sweep_schema_version' must be a non-negative integer")
            })?,
        };
        let name = v
            .get("name")
            .as_str()
            .ok_or_else(|| bad("sweep", "'name' must be a string"))?
            .to_string();
        let base = match v.get("base") {
            Json::Null => {
                return Err(bad(
                    "sweep",
                    "'base' is required (a preset name or an inline scenario object)",
                ))
            }
            Json::Str(s) => BaseRef::Preset(s.clone()),
            obj => BaseRef::Inline(Box::new(
                Scenario::from_json(obj).map_err(|e| bad("sweep.base", format!("{e:#}")))?,
            )),
        };
        let queries = opt_usize(v, "queries", "sweep")?;
        let workers = opt_usize(v, "workers", "sweep")?;
        let lane_workers = opt_usize(v, "lane_workers", "sweep")?;

        let mut axes = Axes::default();
        match v.get("axes") {
            Json::Null => {}
            a => {
                check_keys(a, Axes::KEYS, "sweep.axes")?;
                if let Some(arr) = get_arr(a, "cells", "sweep.axes")? {
                    for (i, x) in arr.iter().enumerate() {
                        axes.cells.push(x.as_usize().ok_or_else(|| {
                            bad(&format!("sweep.axes.cells[{i}]"), "must be a non-negative integer")
                        })?);
                    }
                }
                if let Some(arr) = get_arr(a, "chaos", "sweep.axes")? {
                    for (i, x) in arr.iter().enumerate() {
                        axes.chaos
                            .push(ChaosSpec::from_json(x, &format!("sweep.axes.chaos[{i}]"))?);
                    }
                }
                if let Some(arr) = get_arr(a, "autoscale", "sweep.axes")? {
                    for (i, x) in arr.iter().enumerate() {
                        axes.autoscale.push(AutoscaleSpec::from_json(
                            x,
                            &format!("sweep.axes.autoscale[{i}]"),
                        )?);
                    }
                }
                if let Some(arr) = get_arr(a, "selector", "sweep.axes")? {
                    for (i, x) in arr.iter().enumerate() {
                        let path = format!("sweep.axes.selector[{i}]");
                        let name = x
                            .as_str()
                            .ok_or_else(|| bad(&path, "must be a selector name string"))?;
                        axes.selector.push(
                            SelectorSpec::parse(name).map_err(|e| bad(&path, format!("{e:#}")))?,
                        );
                    }
                }
                if let Some(arr) = get_arr(a, "process", "sweep.axes")? {
                    for (i, x) in arr.iter().enumerate() {
                        axes.process
                            .push(ProcessSpec::from_json(x, &format!("sweep.axes.process[{i}]"))?);
                    }
                }
                if let Some(arr) = get_arr(a, "rate", "sweep.axes")? {
                    for (i, x) in arr.iter().enumerate() {
                        axes.rate
                            .push(RateSpec::from_json(x, &format!("sweep.axes.rate[{i}]"))?);
                    }
                }
                if let Some(arr) = get_arr(a, "gamma0", "sweep.axes")? {
                    for (i, x) in arr.iter().enumerate() {
                        axes.gamma0.push(x.as_f64().ok_or_else(|| {
                            bad(&format!("sweep.axes.gamma0[{i}]"), "must be a number")
                        })?);
                    }
                }
                if let Some(arr) = get_arr(a, "seed", "sweep.axes")? {
                    for (i, x) in arr.iter().enumerate() {
                        axes.seed
                            .push(seed_from_json(x, &format!("sweep.axes.seed[{i}]"))?);
                    }
                }
            }
        }

        let spec = SweepSpec {
            schema_version: schema_version as u32,
            name,
            base,
            queries,
            workers,
            lane_workers,
            axes,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_json_str(text: &str) -> Result<SweepSpec> {
        let v = Json::parse(text).map_err(|e| Error::msg(format!("sweep: {e}")))?;
        SweepSpec::from_json(&v)
    }

    pub fn load(path: &str) -> Result<SweepSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read sweep spec {path}"))?;
        SweepSpec::from_json_str(&text).with_context(|| format!("sweep spec {path}"))
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("write sweep spec {path}"))
    }

    /// FNV-1a checksum of the canonical serialization — independent of
    /// on-disk formatting (the spec is parsed and re-canonicalized
    /// before hashing).
    pub fn digest(&self) -> String {
        crate::telemetry::artifact::checksum(self.to_json().to_string_pretty().as_bytes())
    }

    /// Resolve the base reference to a validated scenario.
    pub fn base_scenario(&self) -> Result<Scenario> {
        match &self.base {
            BaseRef::Preset(name) => crate::scenario::preset(name),
            BaseRef::Inline(s) => {
                s.validate()?;
                Ok((**s).clone())
            }
        }
    }

    /// Structural checks plus a full dry expansion (every point
    /// scenario is validated), so a bad axis value fails at load time
    /// with a field-path diagnostic, not mid-sweep.
    pub fn validate(&self) -> Result<()> {
        crate::ensure!(!self.name.is_empty(), "sweep.name: must not be empty");
        crate::ensure!(
            self.schema_version >= 1 && self.schema_version <= SWEEP_SCHEMA_VERSION,
            "sweep.sweep_schema_version: {} unsupported (this build reads 1..={})",
            self.schema_version,
            SWEEP_SCHEMA_VERSION
        );
        if let Some(q) = self.queries {
            crate::ensure!(q >= 1, "sweep.queries: must be >= 1");
        }
        for (i, &c) in self.axes.cells.iter().enumerate() {
            crate::ensure!(c >= 1, "sweep.axes.cells[{i}]: must be >= 1");
        }
        for (i, &g) in self.axes.gamma0.iter().enumerate() {
            crate::ensure!(
                g > 0.0 && g <= 1.0,
                "sweep.axes.gamma0[{i}]: must be in (0, 1], got {g}"
            );
        }
        self.expand().map(|_| ())
    }

    /// Cartesian product in the fixed nesting order
    /// cells × chaos × autoscale × selector × process × rate × gamma0 ×
    /// seed (seed innermost). Always yields at least one point (the
    /// bare base).
    pub fn expand(&self) -> Result<Vec<SweepPoint>> {
        let base = self.base_scenario()?;
        let cells = slots(&self.axes.cells);
        let chaoses = slots(&self.axes.chaos);
        let autoscales = slots(&self.axes.autoscale);
        let selectors = slots(&self.axes.selector);
        let processes = slots(&self.axes.process);
        let rates = slots(&self.axes.rate);
        let gammas = slots(&self.axes.gamma0);
        let seeds = slots(&self.axes.seed);

        let mut points = Vec::new();
        for c in &cells {
            for ch in &chaoses {
                for a in &autoscales {
                    for sel in &selectors {
                        for pr in &processes {
                            for ra in &rates {
                                for g in &gammas {
                                    for sd in &seeds {
                                        let index = points.len();
                                        let name = format!("p{index:03}");
                                        let (labels, scenario) = self
                                            .apply(&base, &name, c, ch, a, sel, pr, ra, g, sd)?;
                                        points.push(SweepPoint {
                                            index,
                                            name,
                                            labels,
                                            scenario,
                                        });
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(points)
    }

    #[allow(clippy::too_many_arguments)]
    fn apply(
        &self,
        base: &Scenario,
        point: &str,
        cells: &Option<usize>,
        chaos: &Option<ChaosSpec>,
        autoscale: &Option<AutoscaleSpec>,
        selector: &Option<SelectorSpec>,
        process: &Option<ProcessSpec>,
        rate: &Option<RateSpec>,
        gamma0: &Option<f64>,
        seed: &Option<u64>,
    ) -> Result<(Vec<(String, String)>, Scenario)> {
        let mut s = base.clone();
        s.name = format!("{}-{point}", self.name);
        if let Some(q) = self.queries {
            s.traffic.queries = q;
        }
        if let Some(w) = self.workers {
            s.workers = Some(w);
        }
        let mut labels = Vec::new();
        if let Some(n) = *cells {
            labels.push(("cells".to_string(), n.to_string()));
            if n <= 1 {
                s.fleet = None;
            } else {
                let mut f = s.fleet.take().unwrap_or_default();
                f.cells = n;
                s.fleet = Some(f);
            }
        }
        if let Some(lw) = self.lane_workers {
            if let Some(f) = s.fleet.as_mut() {
                f.lane_workers = Some(lw);
            }
        }
        if let Some(c) = chaos {
            labels.push(("chaos".to_string(), c.label()));
            s.chaos = Some(c.clone());
        }
        if let Some(a) = autoscale {
            labels.push(("autoscale".to_string(), a.label()));
            match s.fleet.as_mut() {
                Some(f) => f.autoscale = Some(a.clone()),
                None => crate::bail!(
                    "sweep.axes.autoscale: point {point} is serve-shaped (no fleet) — \
                     autoscale needs a fleet base or a cells axis value > 1"
                ),
            }
        }
        if let Some(sel) = *selector {
            labels.push(("selector".to_string(), sel.name()));
            s.policy.selector = Some(sel);
        }
        if let Some(p) = process {
            labels.push(("process".to_string(), p.label().to_string()));
            s.traffic.process = p.clone();
        }
        if let Some(r) = *rate {
            labels.push(("rate".to_string(), rate_label(&r)));
            s.traffic.rate = r;
        }
        if let Some(g) = *gamma0 {
            // An adaptive-γ base scenario owns γ at runtime: sweeping
            // gamma0 under it would silently fight the controller, so
            // the combination is rejected outright.
            crate::ensure!(
                s.control.is_none(),
                "sweep.axes.gamma0: base scenario enables adaptive γ control \
                 (scenario.control); drop the gamma0 axis or the control section"
            );
            labels.push(("gamma0".to_string(), format!("{g}")));
            match &mut s.policy.kind {
                PolicyKind::Jesa { gamma0, .. } | PolicyKind::LowerBound { gamma0, .. } => {
                    *gamma0 = g;
                }
                _ => {
                    crate::bail!(
                        "sweep.axes.gamma0: base policy must be jesa or lower-bound \
                         to sweep the importance factor"
                    );
                }
            }
        }
        if let Some(sd) = *seed {
            labels.push(("seed".to_string(), sd.to_string()));
            s.system.workload.seed = sd;
        }
        s.validate()
            .with_context(|| format!("sweep point {point}"))?;
        Ok((labels, s))
    }
}

//! Schema-versioned, checksummed run artifacts for bit-for-bit
//! regression diffing.
//!
//! `dmoe run --artifact-dir <d>` writes four files:
//!
//! * `scenario.json` — the canonical pretty-printed scenario spec;
//! * `report.json` — the engine's [`RunReport`] summary JSON;
//! * `telemetry.json` — the [`TelemetryObserver`] snapshot;
//! * `manifest.json` — schema version, scenario name + digest, engine
//!   kind, git revision, wall time, headline perf numbers, and an
//!   FNV-1a checksum + byte length per payload file.
//!
//! Two runs of the same scenario at the same crate revision must produce
//! manifests whose `scenario_digest` and `report_digest` compare
//! bit-identical (`ci.sh` gates this); wall-clock fields (`unix_time_s`,
//! `perf.wall_s`, `perf.wall_qps`) are informational and excluded from
//! that contract. [`verify_artifact`] re-checksums a directory and
//! cross-checks the manifest, for use by `dmoe artifact <dir>`.

use crate::bail;
use crate::scenario::{RunReport, Scenario};
use crate::telemetry::observer::TelemetryObserver;
use crate::util::error::{Context, Result};
use crate::util::hash::Fnv1a;
use crate::util::json::Json;
use std::fs;
use std::path::Path;
use std::time::{SystemTime, UNIX_EPOCH};

/// Version of the artifact directory layout + manifest schema.
pub const ARTIFACT_SCHEMA_VERSION: u64 = 1;

/// FNV-1a digest of a byte string, formatted like the report digests.
pub(crate) fn checksum(bytes: &[u8]) -> String {
    let mut h = Fnv1a::new();
    h.write_bytes(bytes);
    format!("0x{:016x}", h.finish())
}

/// Best-effort git revision: `DMOE_GIT_REV` env override first (CI and
/// tests), then `git rev-parse`, then `"unknown"`.
pub fn git_rev() -> String {
    if let Ok(rev) = std::env::var("DMOE_GIT_REV") {
        if !rev.is_empty() {
            return rev;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|out| out.status.success())
        .and_then(|out| String::from_utf8(out.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Write a complete run artifact into `dir` (created if missing).
/// Returns the manifest that was written.
pub fn write_run_artifact(
    dir: &Path,
    scenario: &Scenario,
    report: &RunReport,
    telemetry: &TelemetryObserver,
) -> Result<Json> {
    fs::create_dir_all(dir).with_context(|| format!("artifact dir {}", dir.display()))?;

    let scenario_text = scenario.to_json().to_string_pretty();
    let report_text = report.to_json().to_string_pretty();
    let telemetry_text = telemetry.snapshot_json().to_string_pretty();

    let unix_time_s = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);

    let mut files = Vec::new();
    for (name, text) in [
        ("scenario.json", &scenario_text),
        ("report.json", &report_text),
        ("telemetry.json", &telemetry_text),
    ] {
        fs::write(dir.join(name), text).with_context(|| format!("write {name}"))?;
        files.push((
            name,
            Json::obj(vec![
                ("bytes", Json::Num(text.len() as f64)),
                ("fnv1a", Json::Str(checksum(text.as_bytes()))),
            ]),
        ));
    }

    let manifest = Json::obj(vec![
        (
            "artifact_schema_version",
            Json::Num(ARTIFACT_SCHEMA_VERSION as f64),
        ),
        (
            "scenario_schema_version",
            Json::Num(scenario.schema_version as f64),
        ),
        ("scenario_name", Json::Str(scenario.name.clone())),
        ("engine", Json::Str(report.kind_name().to_string())),
        ("git_rev", Json::Str(git_rev())),
        ("unix_time_s", Json::Num(unix_time_s as f64)),
        (
            "scenario_digest",
            Json::Str(checksum(scenario_text.as_bytes())),
        ),
        (
            "report_digest",
            Json::Str(format!("0x{:016x}", report.digest())),
        ),
        (
            "perf",
            Json::obj(vec![
                ("wall_s", Json::Num(report.wall_s())),
                ("sim_end_s", Json::Num(report.sim_end_s())),
                ("completed", Json::Num(report.completed() as f64)),
                ("rounds", Json::Num(report.rounds() as f64)),
                (
                    "wall_qps",
                    Json::Num(if report.wall_s() > 0.0 {
                        report.completed() as f64 / report.wall_s()
                    } else {
                        0.0
                    }),
                ),
            ]),
        ),
        ("files", Json::Obj(files.into_iter().map(|(k, v)| (k.to_string(), v)).collect())),
    ]);
    fs::write(dir.join("manifest.json"), manifest.to_string_pretty())
        .context("write manifest.json")?;
    Ok(manifest)
}

/// Verify an artifact directory: parse the manifest, re-checksum every
/// payload file, and cross-check `scenario_digest` against the scenario
/// payload. Returns `(scenario_digest, report_digest)` on success.
pub fn verify_artifact(dir: &Path) -> Result<(String, String)> {
    let manifest_text =
        fs::read_to_string(dir.join("manifest.json")).context("read manifest.json")?;
    let manifest = Json::parse(&manifest_text).context("manifest.json")?;

    let version = manifest.get("artifact_schema_version").as_f64();
    if version != Some(ARTIFACT_SCHEMA_VERSION as f64) {
        bail!(
            "unsupported artifact schema version {:?} (this build reads {})",
            version,
            ARTIFACT_SCHEMA_VERSION
        );
    }

    let files = manifest
        .get("files")
        .as_obj()
        .context("manifest files section missing")?;
    if files.is_empty() {
        bail!("manifest lists no payload files");
    }
    for (name, entry) in files {
        let text =
            fs::read_to_string(dir.join(name)).with_context(|| format!("read {name}"))?;
        let want_bytes = entry.get("bytes").as_f64().unwrap_or(-1.0);
        let want_sum = entry.get("fnv1a").as_str().unwrap_or("");
        if text.len() as f64 != want_bytes {
            bail!(
                "{name}: size mismatch ({} bytes on disk, manifest says {})",
                text.len(),
                want_bytes
            );
        }
        let got_sum = checksum(text.as_bytes());
        if got_sum != want_sum {
            bail!("{name}: checksum mismatch ({got_sum} on disk, manifest says {want_sum})");
        }
    }

    let scenario_text =
        fs::read_to_string(dir.join("scenario.json")).context("read scenario.json")?;
    let scenario_digest = manifest
        .get("scenario_digest")
        .as_str()
        .unwrap_or("")
        .to_string();
    let recomputed = checksum(scenario_text.as_bytes());
    if recomputed != scenario_digest {
        bail!(
            "scenario digest mismatch ({recomputed} recomputed, manifest says {scenario_digest})"
        );
    }
    let report_digest = manifest
        .get("report_digest")
        .as_str()
        .unwrap_or("")
        .to_string();
    if report_digest.is_empty() {
        bail!("manifest report_digest missing");
    }
    // Both report serializers embed the engine digest; an edited
    // manifest digest (or a swapped-in report payload whose entry
    // happens to re-checksum) cannot get past this cross-check.
    let report_text =
        fs::read_to_string(dir.join("report.json")).context("read report.json")?;
    let report = Json::parse(&report_text).context("report.json")?;
    let embedded = report.get("digest").as_str().unwrap_or("");
    if embedded != report_digest {
        bail!("report digest mismatch (report.json says {embedded}, manifest says {report_digest})");
    }
    Ok((scenario_digest, report_digest))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksum_is_stable() {
        assert_eq!(checksum(b""), format!("0x{:016x}", Fnv1a::new().finish()));
        assert_eq!(checksum(b"dmoe"), checksum(b"dmoe"));
        assert_ne!(checksum(b"dmoe"), checksum(b"dmoE"));
    }

    #[test]
    fn git_rev_env_override_wins() {
        // Can't mutate the environment safely in parallel tests; just
        // assert the fallback chain never yields an empty string.
        assert!(!git_rev().is_empty());
    }
}

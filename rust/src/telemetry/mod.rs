//! Observability layer: O(1) streaming statistics, live run status, and
//! schema-versioned run artifacts.
//!
//! The paper's evaluation lives in the *tails* of the latency and energy
//! distributions, so the engines must be able to report p50/p95/p99 over
//! runs far larger than memory allows for per-query vectors. This module
//! provides the machinery, std-only on the `util` substrates:
//!
//! * [`sketch`] — the mergeable [`QuantileSketch`] (bounded relative
//!   error, default 1%) and the [`LatencyStats`] accumulator the reports
//!   embed; the O(1) replacement for stored latency vectors.
//! * [`window`] — [`WindowedCounter`] sliding-window throughput rates
//!   over simulation time (queries/s, tokens/s, sheds/s).
//! * [`trace`] — [`SpanRing`] stage-level tracing (gate → solve →
//!   assign → transmit) with bounded raw-span retention and unbounded
//!   per-stage aggregates.
//! * [`observer`] — [`TelemetryObserver`], the standard
//!   [`EngineObserver`](crate::scenario::EngineObserver) consumer:
//!   per-cell + fleet-wide live stats, commutative merge, and the
//!   `--live` status line.
//! * [`artifact`] — the schema-versioned, checksummed run-artifact
//!   writer behind `dmoe run --artifact-dir` and the `dmoe artifact`
//!   verifier.
//!
//! Everything here is additive to the engines' determinism contract:
//! sketches merge exactly commutatively, and nothing in this module
//! feeds wall-clock time into a report digest.

pub mod artifact;
pub mod observer;
pub mod sketch;
pub mod trace;
pub mod window;

pub use artifact::{git_rev, verify_artifact, write_run_artifact, ARTIFACT_SCHEMA_VERSION};
pub use observer::{CellTelemetry, TelemetryObserver};
pub use sketch::{LatencyStats, QuantileSketch};
pub use trace::{Span, SpanRing, StageStats};
pub use window::WindowedCounter;

//! [`TelemetryObserver`]: the crate's standard [`EngineObserver`]
//! consumer — live fleet-wide and per-cell statistics with O(1) memory
//! per event stream.
//!
//! The observer aggregates every event kind the engines emit
//! ([`RoundEvent`], [`CompletionEvent`], [`ShedEvent`],
//! [`HandoverEvent`], [`ScaleEvent`], final cache stats) into streaming counters,
//! latency sketches and windowed throughput rates. Two observers merge
//! commutatively ([`TelemetryObserver::merge`]): counters are integer
//! adds, sketches merge bucket-wise, and per-cell maps join key-wise —
//! so lane-parallel cells can aggregate in any shard order without
//! perturbing results that feed determinism gates.
//!
//! With [`TelemetryObserver::enable_live`] the observer doubles as the
//! `--live` CLI mode: a wall-clock-throttled one-line status print per
//! interval. Live printing touches only stderr and wall time — never the
//! report or its digest.

use crate::fleet::ScaleEvent;
use crate::scenario::{
    CompletionEvent, EngineObserver, HandoverEvent, RoundEvent, ShedEvent,
};
use crate::serve::CacheStats;
use crate::telemetry::sketch::LatencyStats;
use crate::telemetry::window::WindowedCounter;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Per-cell slice of the aggregate (fleet runs; serve runs use cell 0).
#[derive(Debug, Clone, Default)]
pub struct CellTelemetry {
    pub rounds: u64,
    pub queries: u64,
    pub tokens: u64,
    pub cache_hits: u64,
    pub sheds: u64,
    pub completions: u64,
    pub round_latency: LatencyStats,
    pub query_latency: LatencyStats,
}

impl CellTelemetry {
    fn merge(&mut self, other: &CellTelemetry) {
        self.rounds += other.rounds;
        self.queries += other.queries;
        self.tokens += other.tokens;
        self.cache_hits += other.cache_hits;
        self.sheds += other.sheds;
        self.completions += other.completions;
        self.round_latency.merge(&other.round_latency);
        self.query_latency.merge(&other.query_latency);
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rounds", Json::Num(self.rounds as f64)),
            ("queries", Json::Num(self.queries as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("sheds", Json::Num(self.sheds as f64)),
            ("completions", Json::Num(self.completions as f64)),
            ("round_latency", self.round_latency.to_json()),
            ("query_latency", self.query_latency.to_json()),
        ])
    }
}

/// Streaming telemetry aggregate over an engine run (see module docs).
#[derive(Debug, Clone, Default)]
pub struct TelemetryObserver {
    // Fleet-wide counters.
    pub rounds: u64,
    pub queries: u64,
    pub tokens: u64,
    pub layer_cache_hits: u64,
    pub sheds: u64,
    pub handovers: u64,
    pub completions: u64,
    /// Layers per query round — lets the live line turn layer cache hits
    /// into a hit fraction (hits / (rounds · layers)).
    layers: u64,
    // Streaming distributions.
    pub round_latency: LatencyStats,
    pub query_latency: LatencyStats,
    // Sim-time throughput windows.
    pub query_rate: WindowedCounter,
    pub token_rate: WindowedCounter,
    pub shed_rate: WindowedCounter,
    // Final cache stats (arrives once, at end of run).
    pub cache: Option<CacheStats>,
    per_cell: BTreeMap<u32, CellTelemetry>,
    /// Newest simulation time seen on any round event — the sim-time
    /// anchor for events that carry no timestamp of their own (sheds).
    last_seen_s: f64,
    // `--live` machinery (wall clock only; never feeds reports).
    live_every: Option<Duration>,
    live_started: Option<Instant>,
    live_last: Option<Instant>,
    // Elastic-fleet live state (display only — the elasticity report in
    // the FleetReport is the durable record).
    cells_routable: Option<usize>,
    last_scale: Option<String>,
}

impl TelemetryObserver {
    pub fn new() -> Self {
        Self::default()
    }

    /// Tell the observer how many expert layers one query round solves,
    /// so cache hits can be reported as a fraction.
    pub fn set_layers(&mut self, layers: usize) {
        self.layers = layers as u64;
    }

    /// Turn on `--live` mode: at most one status line per `every` of
    /// wall time, printed to stderr.
    pub fn enable_live(&mut self, every: Duration) {
        self.live_every = Some(every);
        self.live_started = Some(Instant::now());
        self.live_last = None;
    }

    pub fn per_cell(&self) -> &BTreeMap<u32, CellTelemetry> {
        &self.per_cell
    }

    /// Fraction of layer solves served from the solution cache, from
    /// streamed round events.
    pub fn cache_hit_rate(&self) -> f64 {
        let solves = self.rounds * self.layers.max(1);
        if solves == 0 {
            0.0
        } else {
            self.layer_cache_hits as f64 / solves as f64
        }
    }

    /// Fraction of generated queries shed (of those seen so far).
    pub fn shed_fraction(&self) -> f64 {
        let seen = self.queries + self.sheds;
        if seen == 0 {
            0.0
        } else {
            self.sheds as f64 / seen as f64
        }
    }

    /// Commutative merge of two observers (see module docs). Live-mode
    /// settings stay local; the cache report keeps whichever side has
    /// one (they are identical when both do — one shared cache).
    pub fn merge(&mut self, other: &TelemetryObserver) {
        self.rounds += other.rounds;
        self.queries += other.queries;
        self.tokens += other.tokens;
        self.layer_cache_hits += other.layer_cache_hits;
        self.sheds += other.sheds;
        self.handovers += other.handovers;
        self.completions += other.completions;
        self.layers = self.layers.max(other.layers);
        self.last_seen_s = self.last_seen_s.max(other.last_seen_s);
        self.round_latency.merge(&other.round_latency);
        self.query_latency.merge(&other.query_latency);
        self.query_rate.merge(&other.query_rate);
        self.token_rate.merge(&other.token_rate);
        self.shed_rate.merge(&other.shed_rate);
        if self.cache.is_none() {
            self.cache = other.cache.clone();
        }
        for (&cell, slice) in &other.per_cell {
            self.per_cell.entry(cell).or_default().merge(slice);
        }
    }

    fn maybe_print_live(&mut self) {
        let Some(every) = self.live_every else {
            return;
        };
        let now = Instant::now();
        if let Some(last) = self.live_last {
            if now.duration_since(last) < every {
                return;
            }
        }
        self.live_last = Some(now);
        let elapsed = self
            .live_started
            .map(|t| now.duration_since(t).as_secs_f64())
            .unwrap_or(0.0);
        let rounds_per_s = if elapsed > 0.0 {
            self.rounds as f64 / elapsed
        } else {
            0.0
        };
        // Query latency once completions stream; round latency until then.
        let lat = if self.query_latency.count() > 0 {
            &self.query_latency
        } else {
            &self.round_latency
        };
        // Elastic runs append the current routable cell count and the
        // most recent scale action; static runs keep the old line.
        let elastic = match (self.cells_routable, &self.last_scale) {
            (Some(n), Some(ev)) => format!(" | cells {n} ({ev})"),
            (Some(n), None) => format!(" | cells {n}"),
            _ => String::new(),
        };
        eprintln!(
            "[live] wall {elapsed:6.1}s | rounds {} ({rounds_per_s:.0}/s) | q {} \
             | p50 {:.4}s p95 {:.4}s p99 {:.4}s | shed {:.2}% | hit {:.1}% ({} hits){elastic}",
            self.rounds,
            self.queries,
            lat.p50_s(),
            lat.p95_s(),
            lat.p99_s(),
            100.0 * self.shed_fraction(),
            100.0 * self.cache_hit_rate(),
            self.layer_cache_hits,
        );
    }

    /// Full telemetry snapshot — the `telemetry.json` artifact payload.
    pub fn snapshot_json(&self) -> Json {
        let cells = Json::Obj(
            self.per_cell
                .iter()
                .map(|(cell, slice)| (cell.to_string(), slice.to_json()))
                .collect(),
        );
        let cache = match &self.cache {
            Some(c) => Json::obj(vec![
                ("hits", Json::Num(c.hits as f64)),
                ("misses", Json::Num(c.misses as f64)),
                ("evictions", Json::Num(c.evictions as f64)),
                ("hit_rate", Json::Num(c.hit_rate())),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("rounds", Json::Num(self.rounds as f64)),
            ("queries", Json::Num(self.queries as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("layer_cache_hits", Json::Num(self.layer_cache_hits as f64)),
            ("cache_hit_rate", Json::Num(self.cache_hit_rate())),
            ("sheds", Json::Num(self.sheds as f64)),
            ("shed_fraction", Json::Num(self.shed_fraction())),
            ("handovers", Json::Num(self.handovers as f64)),
            ("completions", Json::Num(self.completions as f64)),
            ("round_latency", self.round_latency.to_json()),
            ("query_latency", self.query_latency.to_json()),
            ("query_rate", self.query_rate.to_json()),
            ("token_rate", self.token_rate.to_json()),
            ("shed_rate", self.shed_rate.to_json()),
            ("solution_cache", cache),
            ("cells", cells),
        ])
    }
}

impl EngineObserver for TelemetryObserver {
    fn on_round(&mut self, event: &RoundEvent) {
        self.rounds += 1;
        self.queries += event.queries as u64;
        self.tokens += event.tokens as u64;
        self.layer_cache_hits += event.cache_hits as u64;
        self.round_latency.record(event.latency_s);
        self.query_rate.record(event.start_s, event.queries as f64);
        self.token_rate.record(event.start_s, event.tokens as f64);
        self.last_seen_s = self.last_seen_s.max(event.start_s);
        let slice = self.per_cell.entry(event.cell).or_default();
        slice.rounds += 1;
        slice.queries += event.queries as u64;
        slice.tokens += event.tokens as u64;
        slice.cache_hits += event.cache_hits as u64;
        slice.round_latency.record(event.latency_s);
        self.maybe_print_live();
    }

    fn on_completion(&mut self, event: &CompletionEvent) {
        self.completions += 1;
        self.query_latency.record(event.latency_s());
        let slice = self.per_cell.entry(event.cell).or_default();
        slice.completions += 1;
        slice.query_latency.record(event.latency_s());
    }

    fn on_shed(&mut self, event: &ShedEvent) {
        self.sheds += 1;
        self.per_cell.entry(event.cell).or_default().sheds += 1;
        // Shed events carry no timestamp of their own; anchor on the
        // newest round start seen (sheds surface between rounds).
        self.shed_rate.record(self.last_seen_s, 1.0);
    }

    fn on_handover(&mut self, _event: &HandoverEvent) {
        self.handovers += 1;
    }

    fn on_scale(&mut self, event: &ScaleEvent) {
        self.cells_routable = Some(event.routable_after);
        self.last_scale = Some(format!("{} c{}", event.action.glyph(), event.cell));
        self.maybe_print_live();
    }

    fn on_cache(&mut self, stats: &CacheStats) {
        self.cache = Some(stats.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(cell: u32, start_s: f64, latency_s: f64) -> RoundEvent {
        RoundEvent {
            cell,
            start_s,
            latency_s,
            queries: 4,
            tokens: 64,
            cache_hits: 2,
        }
    }

    #[test]
    fn rounds_accumulate_globally_and_per_cell() {
        let mut t = TelemetryObserver::new();
        t.set_layers(4);
        t.on_round(&round(0, 0.0, 0.1));
        t.on_round(&round(1, 0.5, 0.2));
        assert_eq!(t.rounds, 2);
        assert_eq!(t.queries, 8);
        assert_eq!(t.per_cell()[&1].rounds, 1);
        assert!((t.cache_hit_rate() - 4.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn merge_commutes_on_digest_relevant_fields() {
        let mut a = TelemetryObserver::new();
        let mut b = TelemetryObserver::new();
        a.on_round(&round(0, 0.0, 0.1));
        a.on_completion(&CompletionEvent {
            cell: 0,
            query_id: 1,
            arrival_s: 0.0,
            start_s: 0.0,
            done_s: 0.3,
        });
        b.on_round(&round(1, 1.0, 0.4));
        b.on_completion(&CompletionEvent {
            cell: 1,
            query_id: 2,
            arrival_s: 1.0,
            start_s: 1.0,
            done_s: 1.2,
        });
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.rounds, ba.rounds);
        assert_eq!(ab.completions, ba.completions);
        for q in [50.0, 95.0, 99.0] {
            assert_eq!(
                ab.query_latency.quantile(q).to_bits(),
                ba.query_latency.quantile(q).to_bits()
            );
            assert_eq!(
                ab.round_latency.quantile(q).to_bits(),
                ba.round_latency.quantile(q).to_bits()
            );
        }
        assert_eq!(ab.per_cell().len(), 2);
        assert_eq!(ba.per_cell().len(), 2);
    }

    #[test]
    fn snapshot_serializes() {
        let mut t = TelemetryObserver::new();
        t.set_layers(2);
        t.on_round(&round(0, 0.0, 0.1));
        let j = t.snapshot_json();
        assert_eq!(j.get("rounds").as_f64(), Some(1.0));
        assert_eq!(j.get("cells").get("0").get("rounds").as_f64(), Some(1.0));
    }
}

//! Mergeable streaming quantile sketch with a documented relative error
//! bound — the O(1)-memory replacement for the stored per-query latency
//! vectors in `ServeReport`/`FleetReport`.
//!
//! # Design
//!
//! A log-bucketed histogram in the DDSketch family: values map to
//! geometric buckets `(γ^(k-1), γ^k]` with `γ = (1+α)/(1−α)`, and a
//! quantile query returns the bucket midpoint `2γ^k/(γ+1)` of the bucket
//! containing the target rank. Each insert is O(1); memory is bounded by
//! the *dynamic range* of the data, not the sample count (latencies
//! spanning 1 ns..10⁴ s at the default α occupy ≈ 1500 buckets — a run of
//! 10⁷ queries costs the same as a run of 10³).
//!
//! # Error bound
//!
//! For any quantile `q`, [`QuantileSketch::quantile`] returns a value
//! within **relative error α** (default 1%) of the exact sample at the
//! same nearest rank `round(q/100·(n−1))`, for samples above
//! [`MIN_TRACKED_S`] (smaller values collapse to an exact zero bucket).
//! `ci.sh` gates this bound against the exact debug-path percentiles on
//! every run.
//!
//! # Merge semantics
//!
//! Bucket counts are integers, so [`QuantileSketch::merge`] is exactly
//! commutative and associative: merging per-cell sketches in *any* shard
//! order yields bit-identical counts, min/max and quantiles. The only
//! order-sensitive piece of [`LatencyStats`] is the f64 `sum` behind
//! `mean_s` (float addition is commutative but not associative), which is
//! why engine digests hash quantiles and never the mean.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Default relative accuracy of the sketch (1%).
pub const DEFAULT_ALPHA: f64 = 0.01;

/// Values at or below this threshold (seconds) collapse into the exact
/// zero bucket; the geometric grid only covers values above it.
pub const MIN_TRACKED_S: f64 = 1e-12;

/// Streaming log-bucketed quantile sketch (see the module docs).
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    alpha: f64,
    gamma: f64,
    inv_log_gamma: f64,
    /// Geometric buckets: key `k` holds the count of samples in
    /// `(γ^(k-1), γ^k]`. Sparse — only touched buckets exist.
    buckets: BTreeMap<i32, u64>,
    /// Samples `≤ MIN_TRACKED_S` (exactly representable: reported as 0).
    zero: u64,
    count: u64,
    min: f64,
    max: f64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new(DEFAULT_ALPHA)
    }
}

impl QuantileSketch {
    /// A sketch with relative accuracy `alpha` (0 < alpha < 1).
    pub fn new(alpha: f64) -> Self {
        assert!(
            alpha > 0.0 && alpha < 1.0,
            "sketch alpha must be in (0, 1), got {alpha}"
        );
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        Self {
            alpha,
            gamma,
            inv_log_gamma: 1.0 / gamma.ln(),
            buckets: BTreeMap::new(),
            zero: 0,
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Touched bucket count — the sketch's memory footprint.
    pub fn buckets(&self) -> usize {
        self.buckets.len() + usize::from(self.zero > 0)
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    fn key_of(&self, x: f64) -> i32 {
        // k = ceil(log_γ x); x lands in (γ^(k-1), γ^k].
        (x.ln() * self.inv_log_gamma).ceil() as i32
    }

    fn value_of(&self, key: i32) -> f64 {
        // Bucket midpoint 2γ^k/(γ+1): within α relative of any sample in
        // (γ^(k-1), γ^k].
        2.0 * self.gamma.powi(key) / (self.gamma + 1.0)
    }

    /// Insert one sample. Non-finite samples are counted into the
    /// extremes (min/max) but excluded from the grid; negative samples
    /// collapse into the zero bucket (latencies are non-negative by
    /// construction — this keeps the sketch total-count exact anyway).
    pub fn insert(&mut self, x: f64) {
        self.count += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if !(x > MIN_TRACKED_S) || !x.is_finite() {
            self.zero += 1;
            return;
        }
        *self.buckets.entry(self.key_of(x)).or_insert(0) += 1;
    }

    /// Merge another sketch in (exactly commutative and associative —
    /// integer bucket adds). Panics on α mismatch: sketches on different
    /// grids are not comparable.
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert!(
            self.alpha.to_bits() == other.alpha.to_bits(),
            "cannot merge sketches with different alpha ({} vs {})",
            self.alpha,
            other.alpha
        );
        for (&k, &c) in &other.buckets {
            *self.buckets.entry(k).or_insert(0) += c;
        }
        self.zero += other.zero;
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Quantile estimate, `q` in [0, 100]. Targets the nearest rank
    /// `round(q/100·(n−1))` (same convention as
    /// [`crate::util::stats::nearest_rank`], so the CI accuracy gate
    /// compares like with like) and returns the midpoint of the bucket
    /// holding that rank — within relative α of the exact sample there.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q), "quantile q out of range: {q}");
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q / 100.0 * (self.count - 1) as f64).round() as u64;
        if rank < self.zero {
            return 0.0;
        }
        let mut cum = self.zero;
        for (&k, &c) in &self.buckets {
            cum += c;
            if rank < cum {
                return self.value_of(k);
            }
        }
        // Ranks beyond the grid only exist for non-finite extremes.
        self.max
    }

    /// Summary JSON (counts + canonical quantiles — not the raw buckets).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("alpha", Json::Num(self.alpha)),
            ("buckets", Json::Num(self.buckets() as f64)),
            ("min_s", Json::Num(self.min())),
            ("max_s", Json::Num(self.max())),
            ("p50_s", Json::Num(self.quantile(50.0))),
            ("p90_s", Json::Num(self.quantile(90.0))),
            ("p95_s", Json::Num(self.quantile(95.0))),
            ("p99_s", Json::Num(self.quantile(99.0))),
        ])
    }
}

/// The one-stop streaming latency accumulator the reports and the
/// telemetry observer carry: a [`QuantileSketch`] plus an exact running
/// sum for the mean. O(1) per sample, mergeable (see the module docs for
/// the mean's associativity caveat).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    sketch: QuantileSketch,
    sum: f64,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, seconds: f64) {
        self.sketch.insert(seconds);
        self.sum += seconds;
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.sketch.merge(&other.sketch);
        self.sum += other.sum;
    }

    pub fn count(&self) -> u64 {
        self.sketch.count()
    }

    pub fn is_empty(&self) -> bool {
        self.sketch.is_empty()
    }

    pub fn sum_s(&self) -> f64 {
        self.sum
    }

    pub fn mean_s(&self) -> f64 {
        if self.sketch.count() == 0 {
            0.0
        } else {
            self.sum / self.sketch.count() as f64
        }
    }

    pub fn min_s(&self) -> f64 {
        self.sketch.min()
    }

    pub fn max_s(&self) -> f64 {
        self.sketch.max()
    }

    pub fn quantile(&self, q: f64) -> f64 {
        self.sketch.quantile(q)
    }

    pub fn p50_s(&self) -> f64 {
        self.sketch.quantile(50.0)
    }

    pub fn p95_s(&self) -> f64 {
        self.sketch.quantile(95.0)
    }

    pub fn p99_s(&self) -> f64 {
        self.sketch.quantile(99.0)
    }

    pub fn sketch(&self) -> &QuantileSketch {
        &self.sketch
    }

    pub fn to_json(&self) -> Json {
        let mut j = self.sketch.to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("mean_s".to_string(), Json::Num(self.mean_s()));
            map.insert("sum_s".to_string(), Json::Num(self.sum));
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;
    use crate::util::stats;

    fn assert_within_alpha(sketch: &QuantileSketch, sorted: &[f64], q: f64) {
        let got = sketch.quantile(q);
        let exact = stats::nearest_rank(sorted, q);
        if exact <= MIN_TRACKED_S {
            assert!(got <= MIN_TRACKED_S, "q{q}: zero-bucket sample got {got}");
            return;
        }
        let rel = (got - exact).abs() / exact;
        assert!(
            rel <= sketch.alpha() + 1e-12,
            "q{q}: sketch {got} vs exact {exact} (rel err {rel:.4} > α {})",
            sketch.alpha()
        );
    }

    #[test]
    fn bound_holds_on_random_input() {
        let mut rng = Xoshiro256pp::seed_from_u64(0xA11CE);
        let mut sketch = QuantileSketch::default();
        let mut xs = Vec::new();
        for _ in 0..20_000 {
            // Heavy-ish tail: exp of a uniform spans several decades.
            let x = (6.0 * rng.next_f64() - 3.0).exp() * 1e-3;
            sketch.insert(x);
            xs.push(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            assert_within_alpha(&sketch, &xs, q);
        }
        assert_eq!(sketch.count(), 20_000);
        assert!(sketch.buckets() < 2_000, "footprint {}", sketch.buckets());
    }

    #[test]
    fn bound_holds_on_adversarial_inputs() {
        let cases: Vec<Vec<f64>> = vec![
            vec![0.042; 1000],                       // constant
            vec![1e-9, 1e4].repeat(500),             // two-point, huge range
            (1..=1000).map(|i| i as f64 * 1e-6).collect(), // dense ramp
            vec![0.0, 0.0, 0.0, 1.0, 2.0],           // zeros + values
            vec![5e-13, 0.1],                        // below MIN_TRACKED_S
        ];
        for xs in cases {
            let mut sketch = QuantileSketch::default();
            for &x in &xs {
                sketch.insert(x);
            }
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for q in [0.0, 25.0, 50.0, 75.0, 95.0, 99.0, 100.0] {
                assert_within_alpha(&sketch, &sorted, q);
            }
        }
    }

    #[test]
    fn merge_is_shard_order_invariant() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let shards: Vec<QuantileSketch> = (0..4)
            .map(|_| {
                let mut s = QuantileSketch::default();
                for _ in 0..500 {
                    s.insert(rng.next_f64() * 10.0 + 1e-4);
                }
                s
            })
            .collect();
        let merge_in = |order: &[usize]| {
            let mut acc = QuantileSketch::default();
            for &i in order {
                acc.merge(&shards[i]);
            }
            acc
        };
        let a = merge_in(&[0, 1, 2, 3]);
        let b = merge_in(&[3, 1, 0, 2]);
        assert_eq!(a.count(), b.count());
        assert_eq!(a.min().to_bits(), b.min().to_bits());
        assert_eq!(a.max().to_bits(), b.max().to_bits());
        for q in [1.0, 50.0, 95.0, 99.0] {
            assert_eq!(a.quantile(q).to_bits(), b.quantile(q).to_bits());
        }
    }

    #[test]
    fn empty_sketch_is_safe() {
        let s = QuantileSketch::default();
        assert_eq!(s.quantile(50.0), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.buckets(), 0);
    }

    #[test]
    fn latency_stats_mean_and_quantiles() {
        let mut ls = LatencyStats::new();
        for x in [0.1, 0.2, 0.3, 0.4] {
            ls.record(x);
        }
        assert!((ls.mean_s() - 0.25).abs() < 1e-12);
        assert_eq!(ls.count(), 4);
        let p50 = ls.p50_s();
        assert!((p50 - 0.2).abs() / 0.2 <= DEFAULT_ALPHA + 1e-12, "{p50}");
        let j = ls.to_json();
        assert_eq!(j.get("count").as_f64(), Some(4.0));
    }
}

//! Stage-level tracing spans in a fixed-capacity ring buffer.
//!
//! The engines time the four pipeline stages of every round — `gate`
//! (gate assembly + quantization + cache lookup), `solve` (BCD Block 1
//! expert selection), `assign` (Block 2 subcarrier assignment) and
//! `transmit` (uplink/downlink DES simulation) — and push one span per
//! stage per round. [`SpanRing`] keeps per-stage aggregates (count /
//! total / max) over *all* spans ever recorded, plus the raw tail of the
//! most recent `capacity` spans for inspection, so memory stays bounded
//! on arbitrarily long runs.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Default raw-span retention.
pub const DEFAULT_CAPACITY: usize = 256;

/// One recorded span: monotone sequence number, stage label, duration.
#[derive(Debug, Clone)]
pub struct Span {
    pub seq: u64,
    pub stage: &'static str,
    pub dur_s: f64,
}

/// Per-stage aggregate over every span recorded (not just the retained
/// tail).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageStats {
    pub count: u64,
    pub total_s: f64,
    pub max_s: f64,
}

/// Fixed-capacity span ring with unbounded per-stage aggregates.
#[derive(Debug, Clone)]
pub struct SpanRing {
    capacity: usize,
    next_seq: u64,
    /// Most recent `capacity` spans, oldest first.
    tail: Vec<Span>,
    stages: BTreeMap<&'static str, StageStats>,
}

impl Default for SpanRing {
    fn default() -> Self {
        Self::new(DEFAULT_CAPACITY)
    }
}

impl SpanRing {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "span ring needs capacity ≥ 1");
        Self {
            capacity,
            next_seq: 0,
            tail: Vec::new(),
            stages: BTreeMap::new(),
        }
    }

    pub fn record(&mut self, stage: &'static str, dur_s: f64) {
        let entry = self.stages.entry(stage).or_default();
        entry.count += 1;
        entry.total_s += dur_s;
        entry.max_s = entry.max_s.max(dur_s);
        self.tail.push(Span {
            seq: self.next_seq,
            stage,
            dur_s,
        });
        self.next_seq += 1;
        if self.tail.len() > self.capacity {
            let excess = self.tail.len() - self.capacity;
            self.tail.drain(..excess);
        }
    }

    /// Total spans ever recorded (≥ the retained tail length).
    pub fn recorded(&self) -> u64 {
        self.next_seq
    }

    pub fn tail(&self) -> &[Span] {
        &self.tail
    }

    pub fn stage(&self, stage: &str) -> Option<StageStats> {
        self.stages.get(stage).copied()
    }

    pub fn stages(&self) -> impl Iterator<Item = (&'static str, &StageStats)> {
        self.stages.iter().map(|(&k, v)| (k, v))
    }

    /// Merge another ring: aggregates add; tails interleave by sequence
    /// number and the newest `capacity` spans win. Sequence numbers are
    /// per-ring, so cross-ring ordering is approximate — aggregates,
    /// which the digests and reports consume, are exact.
    pub fn merge(&mut self, other: &SpanRing) {
        for (&stage, s) in &other.stages {
            let entry = self.stages.entry(stage).or_default();
            entry.count += s.count;
            entry.total_s += s.total_s;
            entry.max_s = entry.max_s.max(s.max_s);
        }
        self.tail.extend(other.tail.iter().cloned());
        self.tail.sort_by_key(|s| s.seq);
        if self.tail.len() > self.capacity {
            let excess = self.tail.len() - self.capacity;
            self.tail.drain(..excess);
        }
        self.next_seq = self.next_seq.max(other.next_seq);
    }

    /// Per-stage aggregates as JSON: `{stage: {count, total_s, mean_s,
    /// max_s}}`.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (&stage, s) in &self.stages {
            let mean = if s.count == 0 {
                0.0
            } else {
                s.total_s / s.count as f64
            };
            obj.insert(
                stage.to_string(),
                Json::obj(vec![
                    ("count", Json::Num(s.count as f64)),
                    ("total_s", Json::Num(s.total_s)),
                    ("mean_s", Json::Num(mean)),
                    ("max_s", Json::Num(s.max_s)),
                ]),
            );
        }
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_survive_ring_eviction() {
        let mut ring = SpanRing::new(4);
        for i in 0..10 {
            ring.record("solve", i as f64);
        }
        assert_eq!(ring.tail().len(), 4);
        assert_eq!(ring.recorded(), 10);
        let s = ring.stage("solve").unwrap();
        assert_eq!(s.count, 10);
        assert!((s.total_s - 45.0).abs() < 1e-12);
        assert!((s.max_s - 9.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds_aggregates() {
        let mut a = SpanRing::new(8);
        a.record("gate", 1.0);
        a.record("solve", 2.0);
        let mut b = SpanRing::new(8);
        b.record("solve", 3.0);
        a.merge(&b);
        let s = a.stage("solve").unwrap();
        assert_eq!(s.count, 2);
        assert!((s.total_s - 5.0).abs() < 1e-12);
        assert!((s.max_s - 3.0).abs() < 1e-12);
        assert_eq!(a.stage("gate").unwrap().count, 1);
    }

    #[test]
    fn json_export_has_stage_keys() {
        let mut ring = SpanRing::default();
        ring.record("transmit", 0.5);
        let j = ring.to_json();
        assert_eq!(j.get("transmit").get("count").as_f64(), Some(1.0));
        assert_eq!(j.get("transmit").get("max_s").as_f64(), Some(0.5));
    }
}

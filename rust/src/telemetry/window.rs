//! Windowed throughput counters over *simulation* time.
//!
//! A [`WindowedCounter`] bins recorded amounts into fixed-width sim-time
//! bins and retains only the most recent `window_bins` of them, so the
//! observer can report a recent rate (queries/s, tokens/s, sheds/s)
//! without storing per-event timestamps. Memory is O(window_bins);
//! merging fleets of counters adds bins key-wise and then re-prunes, so
//! the merged window is shard-order invariant.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Default bin width (seconds of simulation time).
pub const DEFAULT_BIN_S: f64 = 0.5;

/// Default number of retained bins (a 16 s sliding window at the
/// default width).
pub const DEFAULT_WINDOW_BINS: usize = 32;

/// Sliding-window rate counter over simulation time (see module docs).
#[derive(Debug, Clone)]
pub struct WindowedCounter {
    bin_s: f64,
    window_bins: usize,
    /// bin index → amount recorded in that bin (only recent bins kept).
    bins: BTreeMap<u64, f64>,
    total: f64,
}

impl Default for WindowedCounter {
    fn default() -> Self {
        Self::new(DEFAULT_BIN_S, DEFAULT_WINDOW_BINS)
    }
}

impl WindowedCounter {
    pub fn new(bin_s: f64, window_bins: usize) -> Self {
        assert!(bin_s > 0.0, "bin width must be positive");
        assert!(window_bins > 0, "window must hold at least one bin");
        Self {
            bin_s,
            window_bins,
            bins: BTreeMap::new(),
            total: 0.0,
        }
    }

    fn bin_of(&self, t_s: f64) -> u64 {
        (t_s.max(0.0) / self.bin_s) as u64
    }

    fn prune(&mut self) {
        while self.bins.len() > self.window_bins {
            let oldest = *self.bins.keys().next().unwrap();
            self.bins.remove(&oldest);
        }
    }

    /// Record `amount` at simulation time `t_s`.
    pub fn record(&mut self, t_s: f64, amount: f64) {
        self.total += amount;
        *self.bins.entry(self.bin_of(t_s)).or_insert(0.0) += amount;
        self.prune();
    }

    /// All-time total of recorded amounts (survives window pruning).
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Recent rate per second over the retained window. "Now" is the
    /// newest bin seen, so the rate is meaningful both mid-run and after
    /// the run ends.
    pub fn rate_per_s(&self) -> f64 {
        if self.bins.is_empty() {
            return 0.0;
        }
        let newest = *self.bins.keys().next_back().unwrap();
        let oldest = *self.bins.keys().next().unwrap();
        let span_s = (newest - oldest + 1) as f64 * self.bin_s;
        self.bins.values().sum::<f64>() / span_s
    }

    /// Merge another counter (same geometry required). Bin-wise float
    /// adds commute; pruning keeps only the newest `window_bins` keys, so
    /// the retained key set is shard-order invariant too.
    pub fn merge(&mut self, other: &WindowedCounter) {
        assert!(
            self.bin_s.to_bits() == other.bin_s.to_bits()
                && self.window_bins == other.window_bins,
            "cannot merge windowed counters with different geometry"
        );
        for (&k, &v) in &other.bins {
            *self.bins.entry(k).or_insert(0.0) += v;
        }
        self.total += other.total;
        self.prune();
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total", Json::Num(self.total)),
            ("rate_per_s", Json::Num(self.rate_per_s())),
            ("bin_s", Json::Num(self.bin_s)),
            ("window_bins", Json::Num(self.window_bins as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_over_retained_window() {
        let mut w = WindowedCounter::new(1.0, 4);
        for t in 0..8 {
            w.record(t as f64, 2.0);
        }
        // Only bins 4..=7 retained: 8 units over 4 s.
        assert!((w.rate_per_s() - 2.0).abs() < 1e-12);
        assert!((w.total() - 16.0).abs() < 1e-12, "total survives pruning");
    }

    #[test]
    fn empty_counter_is_safe() {
        let w = WindowedCounter::default();
        assert_eq!(w.rate_per_s(), 0.0);
        assert_eq!(w.total(), 0.0);
    }

    #[test]
    fn merge_is_order_invariant() {
        let mk = |offset: f64| {
            let mut w = WindowedCounter::new(0.5, 8);
            for i in 0..6 {
                w.record(offset + i as f64 * 0.5, 1.0);
            }
            w
        };
        let (a, b) = (mk(0.0), mk(1.0));
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.rate_per_s().to_bits(), ba.rate_per_s().to_bits());
        assert_eq!(ab.total().to_bits(), ba.total().to_bits());
    }
}

//! Micro-benchmark harness (criterion is not vendored in this
//! environment, so `rust/benches/*.rs` use this in-tree harness with
//! `harness = false`).
//!
//! Behaviour mirrors criterion's core loop: warm-up, then timed samples
//! with an adaptive iteration count targeting a fixed per-sample duration,
//! reporting mean / stddev / p50 / p95 and optional throughput. A
//! `black_box` re-export prevents the optimizer from deleting the
//! benchmarked work.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::stats;

/// Prevent constant folding / dead-code elimination of benchmark inputs
/// and results.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark's results.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time, one entry per sample (seconds).
    pub samples_s: Vec<f64>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples_s)
    }

    pub fn p50_s(&self) -> f64 {
        stats::percentile(&self.samples_s, 50.0)
    }

    pub fn p95_s(&self) -> f64 {
        stats::percentile(&self.samples_s, 95.0)
    }

    pub fn stddev_s(&self) -> f64 {
        stats::stddev(&self.samples_s)
    }
}

fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.2} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.2} ms", s * 1e3)
    } else {
        format!("{s:8.3} s ")
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup: Duration,
    pub target_sample: Duration,
    pub samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            target_sample: Duration::from_millis(50),
            samples: 20,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        let mut b = Self::default();
        // Honor a quick mode for CI-ish runs: DMOE_BENCH_FAST=1.
        if std::env::var("DMOE_BENCH_FAST").as_deref() == Ok("1") {
            b.warmup = Duration::from_millis(50);
            b.target_sample = Duration::from_millis(10);
            b.samples = 8;
        }
        b
    }

    /// Run one benchmark: `f` is invoked repeatedly; its return value is
    /// black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warm-up and calibration: figure out how many iterations fit the
        // target sample duration.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let iters = ((self.target_sample.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut samples_s = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples_s.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            samples_s,
            iters_per_sample: iters,
        };
        println!(
            "{:<48} {}  (p50 {}, p95 {}, sd {}, {} iters/sample)",
            result.name,
            fmt_duration(result.mean_s()),
            fmt_duration(result.p50_s()),
            fmt_duration(result.p95_s()),
            fmt_duration(result.stddev_s()),
            result.iters_per_sample,
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Like [`Bencher::bench`] but also reports items/second throughput.
    pub fn bench_throughput<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items_per_iter: u64,
        f: F,
    ) -> &BenchResult {
        let r = self.bench(name, f);
        let thr = items_per_iter as f64 / r.mean_s();
        println!("{:<48} {:>14.0} items/s", format!("{name} [throughput]"), thr);
        // Reborrow (bench returned a borrow tied to self).
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Emit all results as a JSON report string.
    pub fn to_json(&self) -> String {
        use super::json::Json;
        let arr = Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("name", Json::Str(r.name.clone())),
                        ("mean_s", Json::Num(r.mean_s())),
                        ("p50_s", Json::Num(r.p50_s())),
                        ("p95_s", Json::Num(r.p95_s())),
                        ("stddev_s", Json::Num(r.stddev_s())),
                        ("iters_per_sample", Json::Num(r.iters_per_sample as f64)),
                    ])
                })
                .collect(),
        );
        arr.to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_timings() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            target_sample: Duration::from_millis(2),
            samples: 5,
            results: Vec::new(),
        };
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(black_box(i));
            }
            s
        });
        assert!(r.mean_s() > 0.0);
        assert!(r.mean_s() < 0.1);
        assert_eq!(r.samples_s.len(), 5);
    }

    #[test]
    fn json_report_parses() {
        let mut b = Bencher {
            warmup: Duration::from_millis(2),
            target_sample: Duration::from_millis(1),
            samples: 3,
            results: Vec::new(),
        };
        b.bench("a", || 1 + 1);
        let parsed = crate::util::json::Json::parse(&b.to_json()).unwrap();
        assert_eq!(parsed.at(0).get("name").as_str(), Some("a"));
    }
}

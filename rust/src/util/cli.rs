//! Tiny command-line argument parser (no `clap` in this environment).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` style the `dmoe` binary and the examples use. Callers that
//! know their flag vocabulary should call [`Args::expect`] after
//! parsing: unknown flags are rejected with a "did you mean" suggestion
//! instead of being silently ignored — with scenario files in the mix, a
//! typo'd flag quietly doing nothing is a real footgun.

use crate::util::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args, and named options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an iterator of argument strings.
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut iter = iter.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    let (k, v) = stripped.split_at(eq);
                    args.options.insert(k.to_string(), v[1..].to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Reject any option or boolean flag not in `known`, suggesting the
    /// nearest known flag for likely typos. Call once per subcommand
    /// with its full flag vocabulary.
    pub fn expect(&self, known: &[&str]) -> Result<()> {
        let given = self
            .options
            .keys()
            .map(|k| k.as_str())
            .chain(self.flags.iter().map(|f| f.as_str()));
        for name in given {
            if known.contains(&name) {
                continue;
            }
            let hint = match nearest(name, known) {
                Some(best) => format!(" (did you mean --{best}?)"),
                None => " (see `dmoe help` for the flag list)".to_string(),
            };
            return Err(Error::msg(format!("unknown flag --{name}{hint}")));
        }
        Ok(())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| {
                s.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| {
                s.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|s| {
                s.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }
}

/// The closest known name by edit distance, if close enough to be a
/// plausible typo (distance ≤ 2, or ≤ a third of the name's length for
/// long names; plus prefix matches like `--util` for `--utilization`).
/// Shared by the flag parser and the selector registry's
/// did-you-mean diagnostics.
pub(crate) fn nearest<'a>(name: &str, known: &[&'a str]) -> Option<&'a str> {
    let mut best: Option<(&str, usize)> = None;
    for &cand in known {
        if cand.starts_with(name) || name.starts_with(cand) {
            return Some(cand);
        }
        let d = edit_distance(name, cand);
        if best.map(|(_, bd)| d < bd).unwrap_or(true) {
            best = Some((cand, d));
        }
    }
    match best {
        Some((cand, d)) if d <= 2.max(cand.len() / 3) => Some(cand),
        _ => None,
    }
}

/// Classic Levenshtein distance over bytes (flags are ASCII).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["fig7", "--gamma0", "0.8", "--layers=8", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig7"));
        assert_eq!(a.get_f64("gamma0", 1.0), 0.8);
        assert_eq!(a.get_usize("layers", 0), 8);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["serve"]);
        assert_eq!(a.get_f64("gamma0", 0.7), 0.7);
        assert_eq!(a.get_or("config", "default.toml"), "default.toml");
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = parse(&["run", "input.txt", "out.txt"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["input.txt", "out.txt"]);
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse(&["x", "--json"]);
        assert!(a.flag("json"));
    }

    #[test]
    fn negative_number_as_value() {
        // `--offset -3` : "-3" does not start with "--" so it is a value.
        let a = parse(&["x", "--offset", "-3"]);
        assert_eq!(a.get_f64("offset", 0.0), -3.0);
    }

    #[test]
    fn expect_accepts_known_flags() {
        let a = parse(&["serve", "--queries", "100", "--fixed-quant"]);
        a.expect(&["queries", "fixed-quant", "rate"]).unwrap();
    }

    #[test]
    fn expect_rejects_typo_with_suggestion() {
        let a = parse(&["serve", "--queris", "100"]);
        let err = a.expect(&["queries", "rate", "utilization"]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--queris"), "{msg}");
        assert!(msg.contains("did you mean --queries"), "{msg}");
    }

    #[test]
    fn expect_suggests_on_prefix() {
        let a = parse(&["serve", "--util", "0.5"]);
        let err = a.expect(&["queries", "utilization"]).unwrap_err();
        assert!(err.to_string().contains("--utilization"), "{err}");
    }

    #[test]
    fn expect_rejects_far_off_flags_without_suggestion() {
        let a = parse(&["serve", "--zzzzqqqq", "1"]);
        let err = a.expect(&["queries", "rate"]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown flag --zzzzqqqq"), "{msg}");
        assert!(!msg.contains("did you mean"), "{msg}");
    }

    #[test]
    fn edit_distance_sane() {
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", "abd"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }
}

//! Tiny command-line argument parser (no `clap` in this environment).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` style the `dmoe` binary and the examples use.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args, and named options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an iterator of argument strings.
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut iter = iter.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    let (k, v) = stripped.split_at(eq);
                    args.options.insert(k.to_string(), v[1..].to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| {
                s.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| {
                s.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|s| {
                s.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["fig7", "--gamma0", "0.8", "--layers=8", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig7"));
        assert_eq!(a.get_f64("gamma0", 1.0), 0.8);
        assert_eq!(a.get_usize("layers", 0), 8);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["serve"]);
        assert_eq!(a.get_f64("gamma0", 0.7), 0.7);
        assert_eq!(a.get_or("config", "default.toml"), "default.toml");
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = parse(&["run", "input.txt", "out.txt"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["input.txt", "out.txt"]);
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse(&["x", "--json"]);
        assert!(a.flag("json"));
    }

    #[test]
    fn negative_number_as_value() {
        // `--offset -3` : "-3" does not start with "--" so it is a value.
        let a = parse(&["x", "--offset", "-3"]);
        assert_eq!(a.get_f64("offset", 0.0), -3.0);
    }
}

//! In-tree error type with context support (no `anyhow` in this
//! environment — see the `util` module contract).
//!
//! [`Error`] is a lightweight dynamic error: a message plus an optional
//! chain of causes. It converts from any `std::error::Error` (so `?`
//! works on `io::Error`, [`crate::moe::ManifestError`], …), and the
//! [`Context`] extension trait layers human-readable context the same way
//! `anyhow::Context` does:
//!
//! ```
//! use dmoe::util::error::{Context, Result};
//!
//! fn read(path: &str) -> Result<String> {
//!     std::fs::read_to_string(path).with_context(|| format!("reading {path}"))
//! }
//! assert!(read("/nonexistent").is_err());
//! ```
//!
//! Display prints the outermost message; the alternate form (`{err:#}`)
//! appends the cause chain, which is what the `dmoe` binary prints on
//! failure. The [`crate::bail!`] and [`crate::ensure!`] macros cover the
//! early-return idioms.

use std::fmt;

/// A dynamic error: an outermost message plus an optional cause chain.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

/// Crate-wide result alias (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a plain message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self {
            msg: msg.to_string(),
            cause: None,
        }
    }

    /// Wrap this error as the cause of a new, outer message.
    pub fn context(self, msg: impl fmt::Display) -> Self {
        Self {
            msg: msg.to_string(),
            cause: Some(Box::new(self)),
        }
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        out
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.cause.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.cause.as_deref();
            }
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that keeps the blanket conversion below coherent (same design as the
// ecosystem's dynamic-error crates).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // The repo's typed errors embed their source in Display already
        // (e.g. "cannot read {path}: {io}"), so we take the top message
        // and do not re-walk `source()`.
        Error::msg(e.to_string())
    }
}

/// Extension trait adding context to `Result` and `Option`.
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Attach a lazily-built context message (no cost on the Ok path).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().context(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)).into())
    };
}

/// Assert a condition or early-return with a formatted [`Error`].
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{bail, ensure};

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/dmoe-error-test")
            .with_context(|| "loading the test fixture".to_string())?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = fails_io().unwrap_err();
        assert_eq!(err.to_string(), "loading the test fixture");
        let full = format!("{err:#}");
        assert!(full.starts_with("loading the test fixture: "), "{full}");
        assert!(err.chain().len() == 2);
    }

    #[test]
    fn context_layers_outermost_first() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(e.chain(), vec!["outer", "mid", "root"]);
        assert_eq!(e.root_cause(), "root");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let err = none.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    fn bails(x: i32) -> Result<i32> {
        ensure!(x >= 0, "x must be non-negative, got {x}");
        if x > 100 {
            bail!("x too large: {x}");
        }
        Ok(x)
    }

    #[test]
    fn bail_and_ensure() {
        assert_eq!(bails(7).unwrap(), 7);
        assert_eq!(bails(-1).unwrap_err().to_string(), "x must be non-negative, got -1");
        assert_eq!(bails(101).unwrap_err().to_string(), "x too large: 101");
    }
}

//! Std-only work-stealing task executor (no `rayon` / `crossbeam`
//! vendored).
//!
//! [`parallel_map`](super::pool::parallel_map) covers flat data-parallel
//! sweeps where every item is known up front and one atomic cursor
//! balances the load. The fleet's lane-parallel round execution needs
//! something stronger: a *persistent* worker team that can absorb many
//! small, uneven task batches over the lifetime of one run without
//! re-spawning threads per batch. This module provides exactly that:
//!
//! * **Per-worker deques + steal-half.** Each worker (and the submitting
//!   thread) owns a `Mutex<VecDeque<Task>>`. A batch is dealt round-robin
//!   across the deques; a worker pops from the *front* of its own deque
//!   and, when empty, steals the *back half* of the first non-empty
//!   victim in index order — the classic steal-half discipline that keeps
//!   contention low (one steal rebalances log-many tasks, not one).
//! * **Scoped threads.** Workers are `std::thread::scope` threads spawned
//!   once per [`Executor::scope`] call, so tasks may borrow from the
//!   caller's stack (anything declared before the `scope` call) without
//!   `'static` bounds or unsafe lifetime erasure.
//! * **Submitter participation.** [`TaskScope::run_batch`] blocks until
//!   the batch completes, and the submitting thread drains tasks too, so
//!   `Executor::new(n)` gives `n` degrees of parallelism in total (it
//!   spawns `n - 1` worker threads).
//!
//! Determinism note: the executor never reorders *observable* effects of
//! a correctly-factored batch — tasks must touch disjoint state (or
//! synchronized shared state whose operations commute, like the sharded
//! solution cache), which is exactly how the fleet uses it: one task per
//! cell, each owning that cell's lane.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// A unit of work submitted to the executor. Tasks may borrow anything
/// that outlives the enclosing [`Executor::scope`] call.
pub type Task<'env> = Box<dyn FnOnce() + Send + 'env>;

/// Control block shared by workers and the submitter.
struct Ctl {
    /// Tasks sitting in deques, not yet taken — the workers' sleep
    /// condition (they only run while `queued > 0`). Signed because a
    /// worker draining the previous batch may take freshly pushed tasks
    /// *before* the submitter publishes the batch count; the count goes
    /// transiently negative and settles once `run_batch` adds `n`.
    queued: i64,
    /// Tasks taken-or-queued whose execution has not finished. The
    /// submitter sleeps on this reaching 0.
    pending: usize,
    shutdown: bool,
}

struct Shared<'env> {
    /// One deque per worker plus one for the submitting thread (last).
    queues: Vec<Mutex<VecDeque<Task<'env>>>>,
    ctl: Mutex<Ctl>,
    /// Workers wait here for new work.
    work_cv: Condvar,
    /// The submitter waits here for batch completion.
    done_cv: Condvar,
}

impl<'env> Shared<'env> {
    fn new(slots: usize) -> Self {
        Self {
            queues: (0..slots).map(|_| Mutex::new(VecDeque::new())).collect(),
            ctl: Mutex::new(Ctl {
                queued: 0,
                pending: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        }
    }

    /// Pop from the own deque's front; else steal the back half of the
    /// first non-empty victim (in index order from `home + 1`).
    fn find_task(&self, home: usize) -> Option<Task<'env>> {
        if let Some(t) = self.queues[home].lock().unwrap().pop_front() {
            return Some(t);
        }
        let n = self.queues.len();
        for off in 1..n {
            let victim = (home + off) % n;
            let mut vq = self.queues[victim].lock().unwrap();
            let len = vq.len();
            if len == 0 {
                continue;
            }
            // Steal ceil(len/2) from the back; run one, keep the rest.
            let mut stolen = vq.split_off(len - (len + 1) / 2);
            drop(vq);
            let first = stolen.pop_front();
            if !stolen.is_empty() {
                self.queues[home].lock().unwrap().append(&mut stolen);
            }
            return first;
        }
        None
    }

    /// Take one task, accounting it out of `queued`.
    fn take(&self, home: usize) -> Option<Task<'env>> {
        let task = self.find_task(home)?;
        self.ctl.lock().unwrap().queued -= 1;
        Some(task)
    }

    /// Run one task; `pending` is decremented even if the task panics so
    /// the submitter unblocks and the panic propagates at scope join.
    fn run_one(&self, task: Task<'env>) {
        struct Done<'a, 'env>(&'a Shared<'env>);
        impl Drop for Done<'_, '_> {
            fn drop(&mut self) {
                let mut ctl = self.0.ctl.lock().unwrap();
                ctl.pending -= 1;
                if ctl.pending == 0 {
                    self.0.done_cv.notify_all();
                }
            }
        }
        let _done = Done(self);
        task();
    }

    fn drain(&self, home: usize) {
        while let Some(task) = self.take(home) {
            self.run_one(task);
        }
    }

    fn shutdown(&self) {
        self.ctl.lock().unwrap().shutdown = true;
        self.work_cv.notify_all();
    }
}

fn worker_loop(shared: &Shared<'_>, home: usize) {
    loop {
        shared.drain(home);
        let mut ctl = shared.ctl.lock().unwrap();
        loop {
            if ctl.shutdown {
                return;
            }
            if ctl.queued > 0 {
                break;
            }
            ctl = shared.work_cv.wait(ctl).unwrap();
        }
    }
}

/// A work-stealing executor configuration: total parallelism including
/// the submitting thread. Construction is cheap; worker threads only
/// exist inside [`Executor::scope`].
#[derive(Debug, Clone, Copy)]
pub struct Executor {
    parallelism: usize,
}

impl Executor {
    /// `parallelism` is the total degree of concurrency (submitter
    /// included), clamped to at least 1.
    pub fn new(parallelism: usize) -> Self {
        Self {
            parallelism: parallelism.max(1),
        }
    }

    pub fn parallelism(&self) -> usize {
        self.parallelism
    }

    /// Spawn the worker team for the duration of `f` and hand it a
    /// [`TaskScope`] for submitting batches. Tasks may borrow anything
    /// declared before this call.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&TaskScope<'_, 'env>) -> R,
    {
        let workers = self.parallelism - 1;
        let shared: Shared<'env> = Shared::new(workers + 1);
        std::thread::scope(|s| {
            // Shut the team down even if `f` unwinds — otherwise the
            // scope's implicit join would wait forever on parked workers
            // instead of propagating the panic.
            struct Shutdown<'a, 'env>(&'a Shared<'env>);
            impl Drop for Shutdown<'_, '_> {
                fn drop(&mut self) {
                    self.0.shutdown();
                }
            }
            // Install the guard before spawning: a panic mid-spawn
            // (thread limit) must still release already-parked workers.
            let _shutdown = Shutdown(&shared);
            for w in 0..workers {
                let sh = &shared;
                s.spawn(move || worker_loop(sh, w));
            }
            let scope = TaskScope { shared: &shared };
            f(&scope)
        })
    }
}

/// Handle for submitting task batches to a live worker team. One
/// submitter at a time: `run_batch` is `&self` but batches are meant to
/// be issued from the thread that entered [`Executor::scope`] (tasks
/// must not submit nested batches).
pub struct TaskScope<'pool, 'env> {
    shared: &'pool Shared<'env>,
}

impl<'pool, 'env> TaskScope<'pool, 'env> {
    /// Execute every task in the batch to completion. The calling thread
    /// participates in the work; returns once all tasks have finished.
    pub fn run_batch(&self, mut tasks: Vec<Task<'env>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        if n == 1 {
            // Nothing to parallelize — skip the deque round-trip.
            (tasks.pop().unwrap())();
            return;
        }
        // `pending` is accounted *before* publishing: a worker still
        // draining a previous batch may pick these tasks up the instant
        // they land in a deque, and its decrement must never underflow.
        // `queued` is published *after* the pushes so an awake worker
        // does not busy-spin on empty deques during the push loop (early
        // takes just drive the signed count transiently negative).
        self.shared.ctl.lock().unwrap().pending += n;
        let slots = self.shared.queues.len();
        for (i, task) in tasks.into_iter().enumerate() {
            self.shared.queues[i % slots].lock().unwrap().push_back(task);
        }
        self.shared.ctl.lock().unwrap().queued += n as i64;
        self.shared.work_cv.notify_all();
        // The submitter works from the last deque slot.
        self.shared.drain(slots - 1);
        let mut ctl = self.shared.ctl.lock().unwrap();
        while ctl.pending > 0 {
            ctl = self.shared.done_cv.wait(ctl).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_once() {
        let counter = AtomicUsize::new(0);
        let ex = Executor::new(4);
        ex.scope(|scope| {
            let tasks: Vec<Task<'_>> = (0..100)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Task<'_>
                })
                .collect();
            scope.run_batch(tasks);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn tasks_mutate_disjoint_slots() {
        let slots: Vec<Mutex<u64>> = (0..64).map(|_| Mutex::new(0)).collect();
        let ex = Executor::new(3);
        ex.scope(|scope| {
            let tasks: Vec<Task<'_>> = slots
                .iter()
                .enumerate()
                .map(|(i, slot)| {
                    Box::new(move || {
                        // Uneven work so stealing actually triggers.
                        let mut acc = 0u64;
                        for x in 0..(i as u64 * 500) {
                            acc = acc.wrapping_add(x);
                        }
                        *slot.lock().unwrap() = i as u64 + acc.wrapping_mul(0);
                    }) as Task<'_>
                })
                .collect();
            scope.run_batch(tasks);
        });
        for (i, slot) in slots.iter().enumerate() {
            assert_eq!(*slot.lock().unwrap(), i as u64);
        }
    }

    #[test]
    fn many_batches_reuse_the_team() {
        let counter = AtomicUsize::new(0);
        let ex = Executor::new(4);
        ex.scope(|scope| {
            for _ in 0..50 {
                let tasks: Vec<Task<'_>> = (0..8)
                    .map(|_| {
                        Box::new(|| {
                            counter.fetch_add(1, Ordering::Relaxed);
                        }) as Task<'_>
                    })
                    .collect();
                scope.run_batch(tasks);
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 50 * 8);
    }

    #[test]
    fn empty_and_single_batches() {
        let hit = AtomicUsize::new(0);
        let ex = Executor::new(2);
        ex.scope(|scope| {
            scope.run_batch(Vec::new());
            scope.run_batch(vec![Box::new(|| {
                hit.fetch_add(1, Ordering::Relaxed);
            }) as Task<'_>]);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallelism_one_runs_inline() {
        // No worker threads: the submitter executes everything itself.
        let counter = AtomicUsize::new(0);
        let ex = Executor::new(1);
        assert_eq!(ex.parallelism(), 1);
        ex.scope(|scope| {
            let tasks: Vec<Task<'_>> = (0..10)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Task<'_>
                })
                .collect();
            scope.run_batch(tasks);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn scope_returns_closure_value() {
        let ex = Executor::new(2);
        let out = ex.scope(|scope| {
            scope.run_batch(Vec::new());
            42usize
        });
        assert_eq!(out, 42);
    }
}

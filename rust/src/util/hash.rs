//! Tiny streaming FNV-1a-style hasher over little-endian `u64` words.
//!
//! One shared implementation for every deterministic fingerprint in the
//! repo — the solution-cache's energy-model fingerprint and the fleet
//! report's determinism digest — so the constants cannot silently drift
//! between copies. The multiplier is the repo's historical constant
//! (kept for fingerprint stability); determinism, not cryptography, is
//! the contract.

/// Streaming FNV-1a-style hasher. Feed words with
/// [`Fnv1a::write_u64`], read the digest with [`Fnv1a::finish`].
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    pub fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    /// Mix one word, byte-wise little-endian.
    pub fn write_u64(&mut self, bits: u64) {
        for byte in bits.to_le_bytes() {
            self.0 ^= u64::from(byte);
            self.0 = self.0.wrapping_mul(0x1000_0000_01b3);
        }
    }

    /// Mix a raw byte slice (classic FNV-1a step per byte). Used for
    /// artifact file checksums, where the input is serialized JSON text
    /// rather than `u64` words.
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &byte in bytes {
            self.0 ^= u64::from(byte);
            self.0 = self.0.wrapping_mul(0x1000_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_order_sensitive() {
        let mut a = Fnv1a::new();
        a.write_u64(1);
        a.write_u64(2);
        let mut b = Fnv1a::new();
        b.write_u64(1);
        b.write_u64(2);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv1a::new();
        c.write_u64(2);
        c.write_u64(1);
        assert_ne!(a.finish(), c.finish(), "word order must matter");
    }

    #[test]
    fn distinct_words_distinct_digests() {
        let mut a = Fnv1a::new();
        a.write_u64(0);
        let mut b = Fnv1a::new();
        b.write_u64(1);
        assert_ne!(a.finish(), b.finish());
        assert_ne!(Fnv1a::new().finish(), a.finish(), "empty differs from fed");
    }
}

//! Minimal JSON parser/serializer.
//!
//! The AOT pipeline (`python/compile/aot.py`) writes an
//! `artifacts/manifest.json` describing every exported HLO block; the Rust
//! runtime reads it at startup, and the bench harness emits JSON reports.
//! No `serde` is vendored in this environment, so the repo carries this
//! small, strict RFC-8259 subset implementation (no comments, no trailing
//! commas; `\uXXXX` escapes including surrogate pairs are supported).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data after JSON value"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` lookup; returns `Json::Null` for missing keys or
    /// non-objects, so lookups chain without panicking.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup with the same chaining behaviour as [`Json::get`].
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; emit null like most tolerant encoders.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let code =
                                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(code)
                                        .ok_or_else(|| self.err("invalid surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(hi).ok_or_else(|| self.err("invalid \\u escape"))?
                            };
                            s.push(c);
                            self.pos -= 1; // compensate +1 below
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid UTF-8 in string"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(1).as_f64(), Some(2.0));
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"k":[1,2.5,"s",false,null],"z":{"n":-3}}"#,
            r#"[[],{},[{}],""]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn handles_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" é 😀"));
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.0])),
            ("name", Json::Str("dmoe".into())),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn deterministic_object_order() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }
}

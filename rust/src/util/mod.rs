//! In-tree utility substrates.
//!
//! This build environment vendors only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (`rand`, `serde_json`, `clap`,
//! `criterion`, `rayon`) are unavailable. The repo carries small, tested
//! replacements for exactly the slices it needs:
//!
//! * [`error`] — dynamic error + context chaining (`anyhow` slice) with
//!   the [`crate::bail!`] / [`crate::ensure!`] macros.
//! * [`rng`] — deterministic xoshiro256++ PRNG + distributions.
//! * [`json`] — strict JSON parse/serialize (artifact manifest, reports).
//! * [`cli`] — `--flag value` argument parsing for the binary/examples.
//! * [`bench`] — criterion-style micro-benchmark harness.
//! * [`stats`] — means/percentiles/Welford.
//! * [`pool`] — scoped thread-pool for data-parallel sweeps.
//! * [`executor`] — work-stealing task executor (per-worker deques +
//!   steal-half) for lane-parallel fleet execution.
//! * [`table`] — plain-text table rendering for experiment output.

pub mod bench;
pub mod cli;
pub mod error;
pub mod executor;
pub mod hash;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod table;

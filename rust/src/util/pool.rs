//! Scoped data-parallelism over OS threads (no `rayon` vendored).
//!
//! [`parallel_map`] splits a work list across `n_workers` threads using
//! `std::thread::scope`; order of results matches the input order. Used by
//! the bench harness and Monte-Carlo experiment sweeps, where items are
//! coarse (entire serving runs), so a simple block partition is enough.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` with up to `n_workers` threads, preserving order.
///
/// Work is distributed through an atomic cursor, so uneven item costs
/// still balance. `f` must be `Sync` (it is shared by reference).
///
/// Results are collected lock-free: each worker accumulates `(index,
/// result)` pairs in a thread-local vector that is merged on join, so the
/// only synchronization on the item path is the cursor's `fetch_add` (the
/// original per-item `Mutex<Option<R>>` slots cost one lock round-trip
/// per item).
pub fn parallel_map<T, R, F>(items: &[T], n_workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = n_workers.clamp(1, n);
    if workers == 1 {
        return items.iter().map(&f).collect();
    }

    let cursor = AtomicUsize::new(0);
    let mut results: Vec<Option<R>> = Vec::with_capacity(n);
    results.resize_with(n, || None);

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(&items[i])));
                    }
                    local
                })
            })
            .collect();
        for handle in handles {
            match handle.join() {
                Ok(local) => {
                    for (i, r) in local {
                        results[i] = Some(r);
                    }
                }
                // Re-raise with the original payload so a solver panic's
                // message survives the pool boundary (as it did when the
                // scope itself propagated the unwind).
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });

    results
        .into_iter()
        .map(|slot| slot.expect("worker skipped an item"))
        .collect()
}

/// Number of worker threads to default to (available parallelism, capped).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = parallel_map(&xs, 8, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_fallback() {
        let xs = vec![1, 2, 3];
        assert_eq!(parallel_map(&xs, 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        let ys: Vec<u32> = parallel_map(&xs, 4, |x| *x);
        assert!(ys.is_empty());
    }

    #[test]
    fn uneven_costs_balance() {
        // Items with wildly different costs still all complete.
        let xs: Vec<u64> = (0..64).collect();
        let ys = parallel_map(&xs, 8, |&x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc)
        });
        assert_eq!(ys.len(), 64);
        for (i, (x, _)) in ys.iter().enumerate() {
            assert_eq!(*x, i as u64);
        }
    }
}

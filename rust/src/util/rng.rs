//! Deterministic pseudo-random number generation.
//!
//! The environment vendors no `rand` crate, so the repository carries its
//! own small, well-tested PRNG stack: [`SplitMix64`] for seeding and
//! [`Xoshiro256pp`] (xoshiro256++) as the workhorse generator, plus the
//! distributions the wireless substrate needs (uniform, standard normal
//! via Box–Muller, exponential, and Rayleigh-fading power gains).
//!
//! Every experiment in the repo is seeded, so all tables and figures are
//! exactly reproducible run-to-run.

/// SplitMix64 — used to expand a single `u64` seed into the 256-bit
/// xoshiro state. Reference: Steele, Lea, Flood (2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — fast, high-quality 64-bit PRNG.
/// Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
/// generators" (2019).
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed the generator. Any seed (including 0) is valid; the state is
    /// expanded through SplitMix64 so it is never all-zero.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Self {
        Self::seed_from_u64(self.next_u64())
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `(0, 1]` — safe as a `ln()` argument.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` using Lemire's multiply-shift with
    /// rejection (unbiased).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal N(0,1) via Box–Muller (single value; we discard the
    /// pair partner for simplicity — this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64_open();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// N(mu, sigma^2).
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`), via inversion.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.next_f64_open().ln() / lambda
    }

    /// Rayleigh-fading *power* gain with mean `mean_gain`.
    ///
    /// For Rayleigh fading the envelope is Rayleigh-distributed, so the
    /// power `|h|^2` is exponential. With average path loss `g0` the
    /// channel power gain is `Exp(1/g0)` — mean `g0`, matching the paper's
    /// §VII-A2 ("channel gain follows Rayleigh fading with an average path
    /// loss of 1e-2").
    pub fn rayleigh_power(&mut self, mean_gain: f64) -> f64 {
        self.exponential(1.0 / mean_gain)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized) non-negative weight vector.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: all-zero weights");
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256pp::seed_from_u64(1);
        let mut b = Xoshiro256pp::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn next_below_unbiased_small() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.next_below(5) as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "frac={frac}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Xoshiro256pp::seed_from_u64(13);
        let n = 200_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn rayleigh_power_mean_matches_path_loss() {
        let mut r = Xoshiro256pp::seed_from_u64(17);
        let n = 200_000;
        let mean = (0..n).map(|_| r.rayleigh_power(1e-2)).sum::<f64>() / n as f64;
        assert!((mean - 1e-2).abs() < 2e-4, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Xoshiro256pp::seed_from_u64(23);
        let w = [1.0, 0.0, 3.0];
        let n = 100_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let frac2 = counts[2] as f64 / n as f64;
        assert!((frac2 - 0.75).abs() < 0.01);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Xoshiro256pp::seed_from_u64(29);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}

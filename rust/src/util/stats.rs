//! Small statistics helpers shared by metrics, benches and experiments.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a *sorted copy*; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q), "percentile q out of range: {q}");
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Several percentiles off a *single* sorted copy — use instead of
/// repeated [`percentile`] calls when more than one quantile is needed
/// (each `percentile` call re-sorts the whole slice).
pub fn percentiles(xs: &[f64], qs: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![0.0; qs.len()];
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qs.iter()
        .map(|&q| {
            assert!((0.0..=100.0).contains(&q), "percentile q out of range: {q}");
            let rank = q / 100.0 * (sorted.len() - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            if lo == hi {
                sorted[lo]
            } else {
                let frac = rank - lo as f64;
                sorted[lo] * (1.0 - frac) + sorted[hi] * frac
            }
        })
        .collect()
}

/// Nearest-rank quantile on an *already sorted* slice: the sample at
/// index `round(q/100·(n−1))`. This is the convention the telemetry
/// quantile sketch targets, so exact-vs-sketch comparisons (the CI
/// accuracy gate) are apples-to-apples — linear interpolation between
/// samples would break the sketch's relative-error bound at sparse tails.
pub fn nearest_rank(sorted: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q), "quantile q out of range: {q}");
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Min of a slice (NaN-free assumption).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Max of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.5, -2.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentiles(&[], &[50.0, 99.0]), vec![0.0, 0.0]);
        assert_eq!(nearest_rank(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles_batch_matches_scalar() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        let batch = percentiles(&xs, &[0.0, 50.0, 100.0]);
        for (got, q) in batch.iter().zip([0.0, 50.0, 100.0]) {
            assert!((got - percentile(&xs, q)).abs() < 1e-12);
        }
    }

    #[test]
    fn nearest_rank_picks_samples() {
        let sorted = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(nearest_rank(&sorted, 0.0), 1.0);
        assert_eq!(nearest_rank(&sorted, 50.0), 3.0);
        assert_eq!(nearest_rank(&sorted, 100.0), 5.0);
        // rank = round(0.75·4) = 3 → the 4th sample, never interpolated.
        assert_eq!(nearest_rank(&sorted, 75.0), 4.0);
    }
}

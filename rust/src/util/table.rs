//! Plain-text table rendering for experiment output (Table I, figure data
//! series). Column widths auto-size; numeric cells are right-aligned.

/// A simple text table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Convenience: format a float with `prec` decimals; "-" for None.
    pub fn num(x: Option<f64>, prec: usize) -> String {
        match x {
            Some(v) => format!("{v:.prec$}"),
            None => "-".to_string(),
        }
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let is_numeric = |s: &str| s.parse::<f64>().is_ok() || s == "-";
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        out.push_str(&sep);
        out.push('\n');
        for (i, h) in self.header.iter().enumerate() {
            out.push_str(&format!(" {:<w$} ", h, w = widths[i]));
            if i + 1 < ncols {
                out.push('|');
            }
        }
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if is_numeric(c) {
                    out.push_str(&format!(" {:>w$} ", c, w = widths[i]));
                } else {
                    out.push_str(&format!(" {:<w$} ", c, w = widths[i]));
                }
                if i + 1 < ncols {
                    out.push('|');
                }
            }
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["model", "acc", "energy"]).with_title("Table I");
        t.row(vec!["Top-2".into(), "64.1".into(), "1.00".into()]);
        t.row(vec!["DES(0.6,2)".into(), "64.2".into(), "0.12".into()]);
        let s = t.render();
        assert!(s.contains("Table I"));
        assert!(s.contains("DES(0.6,2)"));
        assert!(s.lines().count() >= 6);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn num_formats() {
        assert_eq!(Table::num(Some(1.23456), 2), "1.23");
        assert_eq!(Table::num(None, 2), "-");
    }
}

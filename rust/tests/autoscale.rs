//! Elastic-fleet coverage: epoch-deterministic autoscale digests
//! (rerun and sequential vs lane-parallel), crash → replacement
//! restoring availability with a finite time-to-recover, drain-on-
//! underload conserving every query, non-uniform fleet round-trips
//! with field-path diagnostics, and the Jain-index regression that
//! non-routable cells no longer dilute the balance metrics.

use dmoe::chaos::ChaosSpec;
use dmoe::fleet::{AutoscaleSpec, CellOverride, FleetReport, MobilityConfig, RoutePolicy};
use dmoe::scenario::{self, Dur, FleetSpec, RateSpec, RunReport, Scenario, TrafficSpec};
use dmoe::SystemConfig;

fn fleet_report(r: RunReport) -> FleetReport {
    match r {
        RunReport::Fleet(f) => f,
        RunReport::Serve(_) => panic!("expected a fleet-shaped report"),
    }
}

/// The self-heal preset cut down to test size, with explicit lanes.
fn selfheal(queries: usize, lane_workers: usize) -> Scenario {
    let mut s = Scenario::preset("crash-storm-selfheal").unwrap();
    s.traffic.queries = queries;
    s.fleet.as_mut().unwrap().lane_workers = Some(lane_workers);
    s
}

/// A tiny elastic fleet sized to sit far below the utilization band, so
/// the controller drains down toward `min_cells`.
fn tiny_underloaded_elastic(queries: usize) -> Scenario {
    let mut cfg = SystemConfig::tiny(); // K=3, L=2, M=12
    cfg.workload.seed = 99;
    Scenario::builder("tiny-elastic-drain")
        .system(cfg)
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Utilization(0.25),
            ..TrafficSpec::default()
        })
        .workers(1)
        .fleet(FleetSpec {
            cells: 3,
            route: RoutePolicy::JoinShortestQueue,
            mobility: MobilityConfig {
                users: 24,
                ..MobilityConfig::default()
            },
            autoscale: Some(AutoscaleSpec {
                period: Dur::Rounds(8.0),
                util_low: 0.55,
                util_high: 0.95,
                shed_high: 0.5,
                min_cells: 1,
                max_cells: 3,
                warmup: Dur::Rounds(1.0),
                heal: false,
                ..AutoscaleSpec::default()
            }),
            lane_workers: Some(0),
            ..FleetSpec::default()
        })
        .build()
        .unwrap()
}

/// A tiny non-uniform fleet: per-cell width, fading, and capacity
/// overrides on an otherwise ordinary 2-cell grid.
fn tiny_nonuniform(queries: usize, lane_workers: usize) -> Scenario {
    let mut cfg = SystemConfig::tiny();
    cfg.workload.seed = 99;
    Scenario::builder("tiny-nonuniform")
        .system(cfg)
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Qps(15.0),
            ..TrafficSpec::default()
        })
        .workers(1)
        .fleet(FleetSpec {
            cells: 2,
            route: RoutePolicy::JoinShortestQueue,
            mobility: MobilityConfig {
                users: 24,
                mean_speed_mps: 12.0,
                ..MobilityConfig::default()
            },
            overrides: vec![
                CellOverride {
                    cell: 0,
                    max_active: Some(2),
                    fading_rho: None,
                    capacity_fraction: Some(0.5),
                    selector: None,
                },
                CellOverride {
                    cell: 1,
                    max_active: None,
                    fading_rho: Some(0.5),
                    capacity_fraction: None,
                    selector: None,
                },
            ],
            lane_workers: Some(lane_workers),
            ..FleetSpec::default()
        })
        .build()
        .unwrap()
}

// -- epoch determinism -------------------------------------------------------

#[test]
fn autoscale_digests_match_on_rerun_and_across_lane_modes() {
    let seq = selfheal(600, 0);
    let par = selfheal(600, 4);
    let a = fleet_report(scenario::run(&seq).unwrap());
    let b = fleet_report(scenario::run(&seq).unwrap());
    let c = fleet_report(scenario::run(&par).unwrap());
    assert_eq!(a.digest(), b.digest(), "autoscale rerun digest");
    assert_eq!(
        a.digest(),
        c.digest(),
        "scale decisions must be bit-identical sequential vs lane-parallel"
    );
    let ea = a.elasticity.as_ref().expect("elasticity block present");
    let ec = c.elasticity.as_ref().unwrap();
    assert_eq!(ea, ec, "identical scale-event logs across lane modes");
    assert!(!ea.events.is_empty(), "the storm must provoke scale events");
}

// -- crash → replacement -----------------------------------------------------

#[test]
fn heal_replaces_crashed_cells_and_recovers() {
    let r = fleet_report(scenario::run(&selfheal(600, 0)).unwrap());
    let chaos = r.chaos.as_ref().expect("chaos report");
    assert_eq!(chaos.crashed_cells, 2, "both scheduled crashes must land");
    let e = r.elasticity.as_ref().expect("elasticity block");
    assert!(e.healed >= 1, "at least one replacement: {e:?}");
    let ttr = e
        .time_to_recover_s
        .expect("first heal must stamp a time-to-recover");
    assert!(ttr.is_finite() && ttr > 0.0, "ttr {ttr}");
    // Replacements bring the routable count back up: the last
    // cells-over-time sample must beat the post-crash trough.
    let trough = e
        .cells_over_time
        .iter()
        .map(|&(_, n)| n)
        .min()
        .expect("trace sampled");
    let last = e.cells_over_time.last().unwrap().1;
    assert!(
        last > trough || trough >= 4,
        "availability must recover: trough {trough}, final {last}"
    );
    assert!(
        r.cells.iter().filter(|c| c.state == "active").count() >= 3,
        "replacements must end the run active"
    );
    assert_eq!(
        r.generated,
        r.completed + r.shed() + r.failed(),
        "healing must not create or lose queries"
    );
}

// -- drain on underload ------------------------------------------------------

#[test]
fn drain_on_underload_conserves_queries() {
    let s = tiny_underloaded_elastic(400);
    let r = fleet_report(scenario::run(&s).unwrap());
    let e = r.elasticity.as_ref().expect("elasticity block");
    assert!(e.drained >= 1, "underload must drain at least one cell: {e:?}");
    assert_eq!(e.healed, 0, "nothing to heal without chaos");
    assert_eq!(
        r.generated,
        r.completed + r.shed() + r.failed(),
        "draining must never drop an in-flight query"
    );
    assert!(r.completed > 0);
    // The victims really left the routable set.
    assert!(
        r.cells.iter().any(|c| c.state == "drained" || c.state == "draining"),
        "a drained cell must surface in the cell table"
    );
}

// -- non-uniform fleets ------------------------------------------------------

#[test]
fn nonuniform_fleet_roundtrips_and_stays_deterministic() {
    let s = tiny_nonuniform(300, 0);
    let j1 = s.to_json().to_string_pretty();
    let back = Scenario::from_json_str(&j1).unwrap();
    assert_eq!(back, s, "overrides must survive the JSON round-trip");
    assert_eq!(back.to_json().to_string_pretty(), j1, "canonical form stable");

    let a = scenario::run(&s).unwrap();
    let b = scenario::run(&tiny_nonuniform(300, 2)).unwrap();
    assert_eq!(
        a.digest(),
        b.digest(),
        "per-cell overrides must stay bit-identical across lane modes"
    );
    // The overrides change the physics: the same fleet without them
    // must land on a different digest.
    let mut plain = s.clone();
    plain.fleet.as_mut().unwrap().overrides.clear();
    let c = scenario::run(&plain).unwrap();
    assert_ne!(a.digest(), c.digest(), "overrides must reach the engine");
}

#[test]
fn override_parse_errors_carry_field_paths() {
    let s = tiny_nonuniform(50, 0);
    let good = s.to_json().to_string_pretty();
    // Breaking the first override's required key must name the exact
    // element, not just "bad fleet".
    let broken = good.replace("\"cell\": 0", "\"sell\": 0");
    assert_ne!(broken, good, "fixture must actually mutate the document");
    let err = Scenario::from_json_str(&broken).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("scenario.fleet.overrides[0]"),
        "want the override field path, got: {msg}"
    );
}

// -- balance metrics ignore non-routable cells (PR 9 bugfix) ----------------

#[test]
fn jain_index_excludes_crashed_cells() {
    let mut cfg = SystemConfig::tiny();
    cfg.workload.seed = 99;
    let s = Scenario::builder("tiny-crash-jain")
        .system(cfg)
        .traffic(TrafficSpec {
            queries: 400,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Qps(15.0),
            ..TrafficSpec::default()
        })
        .workers(1)
        .fleet(FleetSpec {
            cells: 2,
            route: RoutePolicy::JoinShortestQueue,
            mobility: MobilityConfig {
                users: 24,
                mean_speed_mps: 12.0,
                ..MobilityConfig::default()
            },
            lane_workers: Some(0),
            ..FleetSpec::default()
        })
        .chaos(ChaosSpec {
            seed: 9,
            cell_crashes: vec![(1, Dur::Rounds(25.0))],
            ..ChaosSpec::default()
        })
        .build()
        .unwrap();
    let r = fleet_report(scenario::run(&s).unwrap());
    let crashed = r
        .cells
        .iter()
        .find(|c| c.state == "crashed")
        .expect("the scheduled crash must land");
    let survivor = r.cells.iter().find(|c| c.state == "active").unwrap();
    assert!(
        crashed.completed < survivor.completed,
        "the crashed cell stops early ({} vs {})",
        crashed.completed,
        survivor.completed
    );
    // Pre-fix behavior: Jain over *all* cells, diluted by the corpse.
    let xs: Vec<f64> = r.cells.iter().map(|c| c.completed as f64).collect();
    let sum: f64 = xs.iter().sum();
    let sumsq: f64 = xs.iter().map(|x| x * x).sum();
    let all_cells_jain = (sum * sum) / (xs.len() as f64 * sumsq);
    assert!(
        r.jain_index() > all_cells_jain,
        "routable-only Jain {} must beat the diluted all-cells value {}",
        r.jain_index(),
        all_cells_jain
    );
    // With one survivor the routable set is trivially balanced.
    assert!((r.jain_index() - 1.0).abs() < 1e-12);
}

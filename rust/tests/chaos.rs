//! Chaos-layer coverage: bit-identical digests under failure injection
//! (same seed twice, sequential vs lane-parallel fleets), chaos-section
//! JSON round-trips with field-path diagnostics, the degraded-mode QoS
//! surface of the `expert-flap` preset, and the solution-cache
//! regression that a pre-outage solution is never replayed while its
//! expert is down.

use dmoe::chaos::{ChaosSpec, ExpertOutage, LinkFaultSpec};
use dmoe::fleet::{MobilityConfig, RoutePolicy};
use dmoe::scenario::{self, Dur, FleetSpec, RateSpec, Scenario, TrafficSpec};
use dmoe::SystemConfig;

fn tiny_serve(queries: usize, chaos: Option<ChaosSpec>) -> Scenario {
    let mut cfg = SystemConfig::tiny(); // K=3, L=2, M=12
    cfg.workload.seed = 99;
    let mut b = Scenario::builder("tiny-chaos-serve")
        .system(cfg)
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Utilization(0.7),
            ..TrafficSpec::default()
        })
        .workers(1);
    if let Some(c) = chaos {
        b = b.chaos(c);
    }
    b.build().unwrap()
}

fn tiny_fleet(queries: usize, lane_workers: usize, chaos: Option<ChaosSpec>) -> Scenario {
    let mut cfg = SystemConfig::tiny();
    cfg.workload.seed = 99;
    let mut b = Scenario::builder("tiny-chaos-fleet")
        .system(cfg)
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Qps(15.0),
            ..TrafficSpec::default()
        })
        .workers(1)
        .fleet(FleetSpec {
            cells: 2,
            route: RoutePolicy::JoinShortestQueue,
            mobility: MobilityConfig {
                users: 24,
                mean_speed_mps: 12.0,
                ..MobilityConfig::default()
            },
            lane_workers: Some(lane_workers),
            ..FleetSpec::default()
        });
    if let Some(c) = chaos {
        b = b.chaos(c);
    }
    b.build().unwrap()
}

fn serve_chaos() -> ChaosSpec {
    ChaosSpec {
        seed: 7,
        expert_outages: vec![ExpertOutage {
            expert: 1,
            down_at: Dur::Rounds(5.0),
            up_at: Dur::Rounds(40.0),
        }],
        link: Some(LinkFaultSpec {
            fail_prob: 0.25,
            max_retries: 1,
            backoff: Dur::Rounds(0.25),
        }),
        ..ChaosSpec::default()
    }
}

fn fleet_chaos() -> ChaosSpec {
    ChaosSpec {
        seed: 9,
        expert_outages: vec![ExpertOutage {
            expert: 2,
            down_at: Dur::Rounds(4.0),
            up_at: Dur::Rounds(60.0),
        }],
        link: Some(LinkFaultSpec {
            fail_prob: 0.15,
            max_retries: 2,
            backoff: Dur::Rounds(0.25),
        }),
        cell_crashes: vec![(1, Dur::Rounds(25.0))],
        ..ChaosSpec::default()
    }
}

// -- determinism under chaos ------------------------------------------------

#[test]
fn same_chaos_seed_runs_to_identical_digests() {
    let s = tiny_serve(300, Some(serve_chaos()));
    let a = scenario::run(&s).unwrap();
    let b = scenario::run(&s).unwrap();
    assert_eq!(a.digest(), b.digest(), "chaos must be seed-deterministic");
    let c = a.chaos().expect("chaos scenario must report chaos");
    assert!(c.forced_exclusions > 0, "outage window never bit");
    assert_eq!(
        a.generated(),
        a.completed() + a.shed() + a.failed(),
        "conservation under link faults"
    );
    // Perturbing only the chaos seed moves the digest: the fault draws
    // are part of the simulated physics, not cosmetics.
    let mut other = serve_chaos();
    other.seed = 8;
    let d = scenario::run(&tiny_serve(300, Some(other))).unwrap();
    assert_ne!(a.digest(), d.digest(), "chaos seed must reach the engine");
}

#[test]
fn fleet_chaos_sequential_vs_lane_parallel_digests_match() {
    let seq = tiny_fleet(400, 0, Some(fleet_chaos()));
    let par = tiny_fleet(400, 4, Some(fleet_chaos()));
    let a = scenario::run(&seq).unwrap();
    let b = scenario::run(&seq).unwrap();
    let c = scenario::run(&par).unwrap();
    assert_eq!(a.digest(), b.digest(), "sequential rerun digest");
    assert_eq!(
        a.digest(),
        c.digest(),
        "lane-parallel fleet must be bit-identical to sequential under chaos"
    );
    let chaos = a.chaos().expect("fleet chaos report");
    assert_eq!(chaos.crashed_cells, 1, "the scheduled crash must land");
    assert!(a.completed() > 0, "surviving cell must keep serving");
    assert_eq!(
        a.generated(),
        a.completed() + a.shed() + a.failed(),
        "crashed-cell queries must re-route or shed, never vanish"
    );
}

// -- the expert-flap acceptance surface -------------------------------------

#[test]
fn expert_flap_preset_reports_degraded_qos() {
    let mut s = Scenario::preset("expert-flap").unwrap();
    s.traffic.queries = 400;
    let r = scenario::run(&s).unwrap();
    let c = r.chaos().expect("expert-flap must carry a chaos report");
    assert!(r.availability() < 1.0, "availability {}", r.availability());
    assert!(c.retries > 0, "lossy links must retry");
    assert!(c.failed > 0, "some query must exhaust the retry budget");
    assert!(c.forced_exclusions > 0, "the flap must force exclusions");
    assert_eq!(r.generated(), r.completed() + r.shed() + r.failed());
    // Disabling chaos on the very same scenario restores the clean
    // surface: no chaos block, full conservation without `failed`.
    let mut clean = s.clone();
    clean.chaos = None;
    let rc = scenario::run(&clean).unwrap();
    assert!(rc.chaos().is_none(), "chaos-off report must omit the block");
    assert_eq!(rc.failed(), 0);
    assert_eq!(rc.generated(), rc.completed() + rc.shed());
    assert!(!rc.render().contains("chaos:"), "{}", rc.render());
}

// -- JSON round-trip + diagnostics ------------------------------------------

#[test]
fn chaos_sections_roundtrip_scenario_json_bit_identically() {
    for s in [
        tiny_serve(50, Some(serve_chaos())),
        tiny_fleet(50, 0, Some(fleet_chaos())),
    ] {
        let j1 = s.to_json().to_string_pretty();
        assert!(j1.contains("\"chaos\""), "{j1}");
        let back = Scenario::from_json_str(&j1).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.to_json().to_string_pretty(), j1);
    }
    // Chaos-off scenarios serialize without the key at all, so pre-chaos
    // documents and digests are untouched.
    let clean = tiny_serve(50, None);
    assert!(!clean.to_json().to_string_pretty().contains("chaos"));
}

#[test]
fn chaos_errors_carry_field_paths() {
    // Outage missing its recovery time.
    let err = Scenario::from_json_str(
        r#"{"name": "x", "chaos": {"expert_outages": [{"expert": 0, "down_at": {"rounds": 1}}]}}"#,
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("scenario.chaos.expert_outages[0]"), "{msg}");

    // Unknown field inside the link section.
    let err = Scenario::from_json_str(
        r#"{"name": "x", "chaos": {"link": {"fail_prob": 0.1, "retries": 3}}}"#,
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("scenario.chaos.link") && msg.contains("retries"), "{msg}");

    // Cross-field: cell crashes need a fleet section.
    let err = Scenario::from_json_str(
        r#"{"name": "x", "chaos": {"cell_crashes": [[0, {"s": 1.0}]]}}"#,
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("scenario.chaos.cell_crashes") && msg.contains("fleet"), "{msg}");

    // Out-of-range expert against the host system's K.
    let err = Scenario::from_json_str(
        r#"{"name": "x", "chaos": {"expert_outages": [
            {"expert": 99, "down_at": {"rounds": 1}, "up_at": {"rounds": 2}}]}}"#,
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("expert 99 out of range"), "{msg}");
}

// -- the solution-cache live-expert mask regression -------------------------

/// A solution cached while every expert was up must MISS once an expert
/// goes down: the cache key carries the live-expert mask, so the solver
/// re-solves against the degraded pool instead of replaying a selection
/// that routes tokens to the dead expert.
#[test]
fn stale_pre_outage_solution_is_never_served_while_expert_down() {
    use dmoe::config::{ChannelConfig, EnergyConfig};
    use dmoe::energy::EnergyModel;
    use dmoe::gating::{GateScores, SyntheticGate};
    use dmoe::jesa::JesaOptions;
    use dmoe::serve::{solve_quantized, QuantizerConfig, SolutionCache};
    use dmoe::util::rng::Xoshiro256pp;

    let (k, m, tokens) = (4usize, 32usize, 4usize);
    let cfg = ChannelConfig {
        subcarriers: m,
        ..ChannelConfig::default()
    };
    let mut ch = dmoe::channel::ChannelModel::new(cfg.clone(), k, 11);
    let state = ch.realize();
    let mut rng = Xoshiro256pp::seed_from_u64(0xA11CE);
    let gate = SyntheticGate::new(k, 1.0);
    let gates: Vec<Vec<GateScores>> = (0..k)
        .map(|_| (0..tokens).map(|_| gate.sample(&mut rng)).collect())
        .collect();
    let energy = EnergyModel::new(cfg, EnergyConfig::paper(k, 8192.0));
    let quant = QuantizerConfig {
        log2_step: 3.0,
        gate_levels: 32,
    };
    let mut cache = SolutionCache::new(64);
    let up = JesaOptions::default();

    // Warm the cache with the all-experts-up solution…
    let (sol_up, _, hit) =
        solve_quantized(&mut cache, &quant, &state, &gates, 0.5, 2, &energy, &up);
    assert!(!hit, "first solve must miss");
    let (_, _, hit) = solve_quantized(&mut cache, &quant, &state, &gates, 0.5, 2, &energy, &up);
    assert!(hit, "identical inputs must hit");

    // …pick an expert the cached solution actually uses…
    let victim = sol_up
        .selections
        .iter()
        .flatten()
        .flat_map(|s| s.selected.iter().copied())
        .next()
        .expect("solved round selects at least one expert");

    // …then take it down. Identical channel/gates, but the key's
    // live-expert mask differs: the lookup must MISS and the fresh
    // solution must avoid the dead expert entirely.
    let mut down = JesaOptions::default();
    down.offline = vec![false; k];
    down.offline[victim] = true;
    let (sol_down, _, hit) =
        solve_quantized(&mut cache, &quant, &state, &gates, 0.5, 2, &energy, &down);
    assert!(
        !hit,
        "cached pre-outage solution was served while expert {victim} was down"
    );
    assert!(
        sol_down
            .selections
            .iter()
            .flatten()
            .all(|s| !s.selected.contains(&victim)),
        "degraded solve still routed tokens to the dead expert {victim}"
    );
    assert_eq!(cache.len(), 2, "both masks memoize independently");

    // The degraded entry hits on repeat — keyed, not evicted.
    let (sol_again, _, hit) =
        solve_quantized(&mut cache, &quant, &state, &gates, 0.5, 2, &energy, &down);
    assert!(hit);
    assert_eq!(sol_again.selections, sol_down.selections);
}

//! Adaptive control-plane coverage: control-on digests bit-identical
//! across reruns and lane modes (serve + fleet), control-off reports
//! byte-clean of any control section, the AIMD γ law responding in the
//! right direction to overload vs calm, ControlSpec surviving the
//! scenario JSON round-trip with field-path diagnostics, and the two
//! new registry selectors (`channel-gate`, `sift`) reachable by name
//! with "did you mean" suggestions on near-misses.

use dmoe::control::ControlSpec;
use dmoe::fleet::FleetReport;
use dmoe::scenario::{
    self, Dur, PolicySpec, QueueSpec, RateSpec, RunReport, Scenario, TrafficSpec,
};
use dmoe::selection::SelectorSpec;
use dmoe::serve::ServeReport;
use dmoe::SystemConfig;

fn serve_report(r: RunReport) -> ServeReport {
    match r {
        RunReport::Serve(s) => s,
        RunReport::Fleet(_) => panic!("expected a serve-shaped report"),
    }
}

fn fleet_report(r: RunReport) -> FleetReport {
    match r {
        RunReport::Fleet(f) => f,
        RunReport::Serve(_) => panic!("expected a fleet-shaped report"),
    }
}

/// The selector-race preset cut down to test size, with explicit lanes.
fn race(queries: usize, lane_workers: usize) -> Scenario {
    let mut s = Scenario::preset("selector-race").unwrap();
    s.traffic.queries = queries;
    s.fleet.as_mut().unwrap().lane_workers = Some(lane_workers);
    s
}

/// A tiny serve scenario driven far past capacity: a hard queue cap and
/// an 8x arrival overload keep the epoch shed fraction pinned above the
/// band, so every evaluated epoch breaches and γ must relax.
fn tiny_overloaded(queries: usize) -> Scenario {
    let mut cfg = SystemConfig::tiny(); // K=3, L=2, M=12
    cfg.workload.seed = 99;
    Scenario::builder("tiny-overload-control")
        .system(cfg)
        .policy(PolicySpec::jesa(0.8, 2))
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Qps(120.0),
            ..TrafficSpec::default()
        })
        .queue(QueueSpec {
            capacity: Some(8),
            ..QueueSpec::default()
        })
        .workers(1)
        .control(ControlSpec {
            period: Dur::Rounds(2.0),
            warmup: Dur::Rounds(0.0),
            gamma_min: 0.5,
            gamma_max: 0.8,
            ..ControlSpec::default()
        })
        .build()
        .unwrap()
}

/// The same tiny system at 40% utilization with an unbounded queue:
/// nothing ever sheds, so every evaluated epoch is healthy and γ must
/// step up from its lowered start toward the cap.
fn tiny_calm(queries: usize) -> Scenario {
    let mut cfg = SystemConfig::tiny();
    cfg.workload.seed = 99;
    Scenario::builder("tiny-calm-control")
        .system(cfg)
        .policy(PolicySpec::jesa(0.6, 2))
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Utilization(0.4),
            ..TrafficSpec::default()
        })
        .queue(QueueSpec {
            // Effectively unbounded: the calm run must never shed, so
            // every evaluated epoch is a recovery step.
            capacity: Some(100_000),
            deadline: Some(Dur::Rounds(1_000.0)),
            ..QueueSpec::default()
        })
        .workers(1)
        .control(ControlSpec {
            period: Dur::Rounds(2.0),
            warmup: Dur::Rounds(0.0),
            gamma_min: 0.5,
            gamma_max: 0.8,
            ..ControlSpec::default()
        })
        .build()
        .unwrap()
}

// -- control-on determinism --------------------------------------------------

#[test]
fn fleet_control_digests_match_on_rerun_and_across_lane_modes() {
    let seq = race(800, 0);
    let par = race(800, 4);
    let a = fleet_report(scenario::run(&seq).unwrap());
    let b = fleet_report(scenario::run(&seq).unwrap());
    let c = fleet_report(scenario::run(&par).unwrap());
    assert_eq!(a.digest(), b.digest(), "control rerun digest");
    assert_eq!(
        a.digest(),
        c.digest(),
        "γ adjustments must be bit-identical sequential vs lane-parallel"
    );
    let ca = a.control.as_ref().expect("control report present");
    let cc = c.control.as_ref().unwrap();
    assert_eq!(ca, cc, "identical γ trajectories across lane modes");
    assert!(ca.epochs > 0, "the run must cross epoch boundaries");
    for &(_, g) in &ca.trajectory {
        assert!((ca.gamma_min..=ca.gamma_max).contains(&g), "γ {g} in bounds");
    }
}

#[test]
fn serve_control_rerun_is_bit_identical_and_gamma_moves() {
    let mut s = Scenario::preset("adaptive-gamma-flash-crowd").unwrap();
    s.traffic.queries = 1200;
    let a = serve_report(scenario::run(&s).unwrap());
    let b = serve_report(scenario::run(&s).unwrap());
    assert_eq!(a.digest(), b.digest(), "serve control rerun digest");
    let c = a.control.as_ref().expect("control report present");
    assert!(
        c.adjustments >= 1 && c.trajectory.len() >= 2,
        "the controller must actually move γ: {c:?}"
    );
    let mut gammas: Vec<u64> = c.trajectory.iter().map(|&(_, g)| g.to_bits()).collect();
    gammas.dedup();
    assert!(gammas.len() >= 2, "want >= 2 distinct γ values: {c:?}");
    assert!(
        (c.gamma_min..=c.gamma_max).contains(&c.settled_gamma),
        "settled γ {} must land inside [{}, {}]",
        c.settled_gamma,
        c.gamma_min,
        c.gamma_max
    );
}

// -- control-off byte-identity -----------------------------------------------

#[test]
fn control_off_reports_carry_no_control_section() {
    // Serve shape: the paper baseline has no control section, so its
    // report JSON/render must be byte-identical to pre-control builds.
    let mut s = Scenario::preset("paper-baseline").unwrap();
    s.traffic.queries = 400;
    assert!(s.control.is_none());
    assert!(!s.to_json().to_string_pretty().contains("\"control\""));
    let r = scenario::run(&s).unwrap();
    assert!(r.control().is_none());
    let serve = serve_report(r);
    assert!(!serve.to_json().to_string_pretty().contains("\"control\""));
    assert!(!serve.render().contains("control: gamma"));

    // Fleet shape.
    let mut s = Scenario::preset("urban-macro-jsq").unwrap();
    s.traffic.queries = 400;
    s.fleet.as_mut().unwrap().lane_workers = Some(0);
    let r = scenario::run(&s).unwrap();
    assert!(r.control().is_none());
    let fleet = fleet_report(r);
    assert!(!fleet.to_json().to_string_pretty().contains("\"control\""));
    assert!(!fleet.render().contains("control: gamma"));
}

// -- the AIMD law responds in the right direction ----------------------------

#[test]
fn overload_relaxes_gamma_toward_the_floor() {
    let r = serve_report(scenario::run(&tiny_overloaded(600)).unwrap());
    assert!(r.shed_queue_full > 0, "the overload must shed");
    let c = r.control.as_ref().expect("control report present");
    assert!(c.adjustments >= 1, "sustained breach must relax γ: {c:?}");
    assert!(
        c.trajectory[1].1 < c.trajectory[0].1,
        "the first adjustment must relax, not recover: {c:?}"
    );
    assert!(
        c.settled_gamma < 0.8,
        "γ must settle below its start under overload: {c:?}"
    );
    assert!(c.settled_gamma >= c.gamma_min - 1e-12);
    assert!(
        c.shed_frac_at_settle > 0.0,
        "the settle epoch must report its shed pressure"
    );
}

#[test]
fn calm_traffic_recovers_gamma_monotonically() {
    let r = serve_report(scenario::run(&tiny_calm(500)).unwrap());
    assert_eq!(r.shed_queue_full + r.shed_deadline, 0, "nothing sheds");
    let c = r.control.as_ref().expect("control report present");
    assert!(c.adjustments >= 1, "healthy epochs must recover γ: {c:?}");
    for w in c.trajectory.windows(2) {
        assert!(
            w[1].1 > w[0].1,
            "with zero shed every move is a recovery step: {c:?}"
        );
    }
    assert!(
        c.settled_gamma > 0.6 && c.settled_gamma <= 0.8 + 1e-12,
        "γ climbs from 0.6 toward the 0.8 cap: {c:?}"
    );
}

// -- JSON round-trip + diagnostics -------------------------------------------

#[test]
fn control_scenarios_roundtrip_bit_identically() {
    for name in ["selector-race", "adaptive-gamma-flash-crowd"] {
        let s = Scenario::preset(name).unwrap();
        let j1 = s.to_json().to_string_pretty();
        let back = Scenario::from_json_str(&j1).unwrap();
        assert_eq!(back, s, "{name}: control must survive the round-trip");
        assert_eq!(back.to_json().to_string_pretty(), j1, "{name}: canonical");
    }
}

#[test]
fn control_errors_carry_field_paths() {
    // Unknown key inside the control section names the exact path.
    let good = Scenario::preset("selector-race")
        .unwrap()
        .to_json()
        .to_string_pretty();
    let broken = good.replacen("\"step\"", "\"stepp\"", 1);
    assert_ne!(broken, good, "fixture must actually mutate the document");
    let msg = format!("{:#}", Scenario::from_json_str(&broken).unwrap_err());
    assert!(msg.contains("scenario.control"), "{msg}");

    // Semantic validation walks the same path.
    let mut s = Scenario::preset("selector-race").unwrap();
    s.control.as_mut().unwrap().relax = 1.5;
    let msg = format!("{:#}", s.validate().unwrap_err());
    assert!(msg.contains("scenario.control"), "{msg}");

    // Control without a jesa policy is rejected up front.
    let mut s = Scenario::preset("low-qos-energy-saver").unwrap();
    s.control = Some(ControlSpec::default());
    let msg = format!("{:#}", s.validate().unwrap_err());
    assert!(msg.contains("jesa"), "{msg}");
}

// -- the new selectors reach the registry ------------------------------------

#[test]
fn channel_gate_and_sift_run_by_name() {
    let mut cfg = SystemConfig::tiny();
    cfg.workload.seed = 99;
    for sel in [SelectorSpec::ChannelGate, SelectorSpec::Sift] {
        let s = Scenario::builder(&format!("tiny-{}", sel.name()))
            .system(cfg.clone())
            .policy(PolicySpec::jesa(0.8, 2).with_selector(sel))
            .traffic(TrafficSpec {
                queries: 300,
                domains: 4,
                tokens_per_query: 2,
                rate: RateSpec::Utilization(0.5),
                ..TrafficSpec::default()
            })
            .workers(1)
            .build()
            .unwrap();
        // The selector name survives the scenario round-trip...
        let j = s.to_json().to_string_pretty();
        assert!(j.contains(&sel.name()), "{j}");
        assert_eq!(Scenario::from_json_str(&j).unwrap(), s);
        // ...and the run actually completes work through it.
        let r = serve_report(scenario::run(&s).unwrap());
        assert!(r.completed > 0, "{} must complete queries", sel.name());
        assert_eq!(scenario::run(&s).unwrap().digest(), {
            let again = serve_report(scenario::run(&s).unwrap());
            again.digest()
        });
    }
}

#[test]
fn near_miss_selector_names_get_a_suggestion() {
    let s = Scenario::builder("tiny-suggest")
        .policy(PolicySpec::jesa(0.8, 2).with_selector(SelectorSpec::ChannelGate))
        .traffic(TrafficSpec {
            queries: 10,
            ..TrafficSpec::default()
        })
        .build()
        .unwrap();
    let good = s.to_json().to_string_pretty();
    let broken = good.replacen("channel-gate", "chanel-gate", 1);
    assert_ne!(broken, good);
    let msg = format!("{:#}", Scenario::from_json_str(&broken).unwrap_err());
    assert!(
        msg.contains("did you mean 'channel-gate'?"),
        "want a registry suggestion, got: {msg}"
    );
}

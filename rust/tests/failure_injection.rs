//! Failure-injection tests: malformed artifacts, bad configs and
//! degenerate workloads must fail loudly with useful errors — never
//! panic, hang, or silently serve garbage.

use dmoe::coordinator::{DmoeServer, ServePolicy};
use dmoe::moe::Manifest;
use dmoe::runtime::ModelRuntime;
use dmoe::workload::{EvalSet, Query};
use dmoe::SystemConfig;

fn temp_dir(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("dmoe-fi-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.to_str().unwrap().to_string()
}

#[test]
fn missing_manifest_errors_cleanly() {
    let dir = temp_dir("none");
    let err = Manifest::load(&dir).unwrap_err();
    assert!(err.to_string().contains("manifest.json"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_manifest_json_errors() {
    let dir = temp_dir("corrupt");
    std::fs::write(format!("{dir}/manifest.json"), "{ not json !!!").unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(err.to_string().to_lowercase().contains("parse"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_with_missing_hlo_files_fails_at_load() {
    let dir = temp_dir("nohlo");
    std::fs::write(
        format!("{dir}/manifest.json"),
        r#"{
          "model": {"vocab":16,"d_model":8,"ffn":16,"experts":1,"layers":1,"heads":2,"seq_len":4},
          "blocks": {"embed":"embed.hlo.txt","head":"head.hlo.txt",
                     "attn":["a0.hlo.txt"],"gate":["g0.hlo.txt"],"ffn":[["f00.hlo.txt"]]}
        }"#,
    )
    .unwrap();
    // Manifest parses (structure is valid)…
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.model.experts, 1);
    // …but the runtime must fail on the missing HLO file with context.
    let err = match ModelRuntime::load(&dir) {
        Ok(_) => panic!("runtime loaded with missing HLO files"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("embed.hlo.txt"), "error lacks file context: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_hlo_text_fails_to_parse() {
    let real = std::env::var("DMOE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&format!("{real}/manifest.json")).exists() {
        eprintln!("skipping: needs artifacts");
        return;
    }
    let dir = temp_dir("trunc");
    // Copy the manifest + all blocks, then truncate one block file.
    for entry in std::fs::read_dir(&real).unwrap() {
        let p = entry.unwrap().path();
        if let Some(name) = p.file_name().and_then(|n| n.to_str()) {
            if name.ends_with(".json") || name.ends_with(".hlo.txt") {
                std::fs::copy(&p, format!("{dir}/{name}")).unwrap();
            }
        }
    }
    let manifest = Manifest::load(&dir).unwrap();
    let victim = manifest.path(&manifest.embed);
    let text = std::fs::read_to_string(&victim).unwrap();
    std::fs::write(&victim, &text[..text.len() / 3]).unwrap();
    assert!(
        ModelRuntime::load(&dir).is_err(),
        "truncated HLO must not load"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_queries_rejected() {
    if !dmoe::runtime::pjrt_available() {
        eprintln!("skipping: built without the `xla` feature (no PJRT runtime)");
        return;
    }
    let dir = std::env::var("DMOE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("skipping: needs artifacts");
        return;
    }
    let mut cfg = SystemConfig::default();
    cfg.artifacts_dir = dir;
    let mut server = DmoeServer::new(&cfg).unwrap();
    let layers = server.layers();
    let policy = ServePolicy::jesa(0.8, 2, layers);

    // Source expert out of range.
    let q = Query {
        id: 0,
        source_expert: 99,
        tokens: vec![1, 2, 3],
        labels: vec![2, 3, 4],
        domain: 0,
    };
    assert!(server.serve_batch(&[q], &policy).is_err());

    // Oversized token block.
    let seq = server.runtime().seq_len();
    let q = Query {
        id: 1,
        source_expert: 0,
        tokens: vec![0; seq + 1],
        labels: vec![0; seq + 1],
        domain: 0,
    };
    assert!(server.serve_batch(&[q], &policy).is_err());

    // Empty query.
    let q = Query {
        id: 2,
        source_expert: 0,
        tokens: vec![],
        labels: vec![],
        domain: 0,
    };
    assert!(server.serve_batch(&[q], &policy).is_err());

    // Duplicate source assignment.
    let mk = |id| Query {
        id,
        source_expert: 0,
        tokens: vec![1, 2],
        labels: vec![2, 3],
        domain: 0,
    };
    assert!(server.serve_batch(&[mk(3), mk(4)], &policy).is_err());

    // Wrong importance-schedule width.
    let bad_policy = ServePolicy::jesa(0.8, 2, layers + 1);
    assert!(server.serve_batch(&[mk(5)], &bad_policy).is_err());

    // And a healthy query still works afterwards (server not poisoned).
    let ok = server.serve_batch(&[mk(6)], &policy).unwrap();
    assert_eq!(ok.total, 2);
}

#[test]
fn eval_set_parse_failures() {
    let dir = temp_dir("eval");
    let path = format!("{dir}/bad.json");
    std::fs::write(&path, r#"{"name":"x","mixture":[1.0],"tokens":"nope"}"#).unwrap();
    assert!(EvalSet::load(&path).is_err());
    std::fs::write(
        &path,
        r#"{"name":"x","mixture":[1.0],"tokens":[[1]],"labels":[[2]],"domains":["zero"]}"#,
    )
    .unwrap();
    assert!(EvalSet::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn invalid_configs_rejected_before_serving() {
    let mut cfg = SystemConfig::default();
    cfg.moe.max_active = 0;
    assert!(cfg.validate().is_err());
    cfg = SystemConfig::default();
    cfg.channel.path_loss = 0.0;
    assert!(cfg.validate().is_err());
}

//! Failure-injection tests. Two layers:
//!
//! * **Load-time**: malformed artifacts, bad configs and degenerate
//!   workloads must fail loudly with useful errors — never panic,
//!   hang, or silently serve garbage.
//! * **Runtime chaos** (see [`dmoe::chaos`]): scheduled expert
//!   outages, lossy links and cell crashes injected mid-run must keep
//!   the engines honest — down experts never selected, recovery
//!   restores them, and every admitted query is accounted for as
//!   completed, shed, or failed.

use dmoe::chaos::{ChaosSpec, ExpertOutage, LinkFaultSpec};
use dmoe::coordinator::{DmoeServer, ServePolicy};
use dmoe::moe::Manifest;
use dmoe::runtime::ModelRuntime;
use dmoe::scenario::{self, Dur, RunReport};
use dmoe::workload::{EvalSet, Query};
use dmoe::SystemConfig;

fn temp_dir(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("dmoe-fi-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.to_str().unwrap().to_string()
}

#[test]
fn missing_manifest_errors_cleanly() {
    let dir = temp_dir("none");
    let err = Manifest::load(&dir).unwrap_err();
    assert!(err.to_string().contains("manifest.json"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_manifest_json_errors() {
    let dir = temp_dir("corrupt");
    std::fs::write(format!("{dir}/manifest.json"), "{ not json !!!").unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(err.to_string().to_lowercase().contains("parse"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_with_missing_hlo_files_fails_at_load() {
    let dir = temp_dir("nohlo");
    std::fs::write(
        format!("{dir}/manifest.json"),
        r#"{
          "model": {"vocab":16,"d_model":8,"ffn":16,"experts":1,"layers":1,"heads":2,"seq_len":4},
          "blocks": {"embed":"embed.hlo.txt","head":"head.hlo.txt",
                     "attn":["a0.hlo.txt"],"gate":["g0.hlo.txt"],"ffn":[["f00.hlo.txt"]]}
        }"#,
    )
    .unwrap();
    // Manifest parses (structure is valid)…
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.model.experts, 1);
    // …but the runtime must fail on the missing HLO file with context.
    let err = match ModelRuntime::load(&dir) {
        Ok(_) => panic!("runtime loaded with missing HLO files"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("embed.hlo.txt"), "error lacks file context: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_hlo_text_fails_to_parse() {
    let real = std::env::var("DMOE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&format!("{real}/manifest.json")).exists() {
        eprintln!("skipping: needs artifacts");
        return;
    }
    let dir = temp_dir("trunc");
    // Copy the manifest + all blocks, then truncate one block file.
    for entry in std::fs::read_dir(&real).unwrap() {
        let p = entry.unwrap().path();
        if let Some(name) = p.file_name().and_then(|n| n.to_str()) {
            if name.ends_with(".json") || name.ends_with(".hlo.txt") {
                std::fs::copy(&p, format!("{dir}/{name}")).unwrap();
            }
        }
    }
    let manifest = Manifest::load(&dir).unwrap();
    let victim = manifest.path(&manifest.embed);
    let text = std::fs::read_to_string(&victim).unwrap();
    std::fs::write(&victim, &text[..text.len() / 3]).unwrap();
    assert!(
        ModelRuntime::load(&dir).is_err(),
        "truncated HLO must not load"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_queries_rejected() {
    if !dmoe::runtime::pjrt_available() {
        eprintln!("skipping: built without the `xla` feature (no PJRT runtime)");
        return;
    }
    let dir = std::env::var("DMOE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("skipping: needs artifacts");
        return;
    }
    let mut cfg = SystemConfig::default();
    cfg.artifacts_dir = dir;
    let mut server = DmoeServer::new(&cfg).unwrap();
    let layers = server.layers();
    let policy = ServePolicy::jesa(0.8, 2, layers);

    // Source expert out of range.
    let q = Query {
        id: 0,
        source_expert: 99,
        tokens: vec![1, 2, 3],
        labels: vec![2, 3, 4],
        domain: 0,
    };
    assert!(server.serve_batch(&[q], &policy).is_err());

    // Oversized token block.
    let seq = server.runtime().seq_len();
    let q = Query {
        id: 1,
        source_expert: 0,
        tokens: vec![0; seq + 1],
        labels: vec![0; seq + 1],
        domain: 0,
    };
    assert!(server.serve_batch(&[q], &policy).is_err());

    // Empty query.
    let q = Query {
        id: 2,
        source_expert: 0,
        tokens: vec![],
        labels: vec![],
        domain: 0,
    };
    assert!(server.serve_batch(&[q], &policy).is_err());

    // Duplicate source assignment.
    let mk = |id| Query {
        id,
        source_expert: 0,
        tokens: vec![1, 2],
        labels: vec![2, 3],
        domain: 0,
    };
    assert!(server.serve_batch(&[mk(3), mk(4)], &policy).is_err());

    // Wrong importance-schedule width.
    let bad_policy = ServePolicy::jesa(0.8, 2, layers + 1);
    assert!(server.serve_batch(&[mk(5)], &bad_policy).is_err());

    // And a healthy query still works afterwards (server not poisoned).
    let ok = server.serve_batch(&[mk(6)], &policy).unwrap();
    assert_eq!(ok.total, 2);
}

#[test]
fn eval_set_parse_failures() {
    let dir = temp_dir("eval");
    let path = format!("{dir}/bad.json");
    std::fs::write(&path, r#"{"name":"x","mixture":[1.0],"tokens":"nope"}"#).unwrap();
    assert!(EvalSet::load(&path).is_err());
    std::fs::write(
        &path,
        r#"{"name":"x","mixture":[1.0],"tokens":[[1]],"labels":[[2]],"domains":["zero"]}"#,
    )
    .unwrap();
    assert!(EvalSet::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn invalid_configs_rejected_before_serving() {
    let mut cfg = SystemConfig::default();
    cfg.moe.max_active = 0;
    assert!(cfg.validate().is_err());
    cfg = SystemConfig::default();
    cfg.channel.path_loss = 0.0;
    assert!(cfg.validate().is_err());
}

// -- runtime chaos: injected failures mid-run --------------------------------

use dmoe::fleet::{MobilityConfig, RoutePolicy};
use dmoe::scenario::{FleetSpec, RateSpec, Scenario, TrafficSpec};

fn chaos_serve(queries: usize, chaos: ChaosSpec) -> Scenario {
    let mut cfg = SystemConfig::tiny(); // K=3, L=2, M=12
    cfg.workload.seed = 99;
    Scenario::builder("fi-chaos-serve")
        .system(cfg)
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Utilization(0.7),
            ..TrafficSpec::default()
        })
        .workers(1)
        .chaos(chaos)
        .build()
        .unwrap()
}

fn chaos_fleet(queries: usize, chaos: ChaosSpec) -> Scenario {
    let mut cfg = SystemConfig::tiny();
    cfg.workload.seed = 99;
    Scenario::builder("fi-chaos-fleet")
        .system(cfg)
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Qps(15.0),
            ..TrafficSpec::default()
        })
        .workers(1)
        .fleet(FleetSpec {
            cells: 2,
            route: RoutePolicy::JoinShortestQueue,
            mobility: MobilityConfig {
                users: 24,
                mean_speed_mps: 12.0,
                ..MobilityConfig::default()
            },
            lane_workers: Some(0),
            ..FleetSpec::default()
        })
        .chaos(chaos)
        .build()
        .unwrap()
}

/// Summed selection probability of one expert across every layer —
/// zero means the expert was never selected in any round.
fn selection_mass(r: &RunReport, expert: usize) -> f64 {
    let p = r.pattern();
    (0..p.layers()).map(|l| p.probability(l, expert)).sum()
}

fn conserve(r: &RunReport) {
    assert_eq!(
        r.generated(),
        r.completed() + r.shed() + r.failed(),
        "query conservation: generated {} != completed {} + shed {} + failed {}",
        r.generated(),
        r.completed(),
        r.shed(),
        r.failed()
    );
}

#[test]
fn outage_mid_run_forces_exclusion_and_recovery_restores() {
    // Chaos-free baseline: find the expert the policy leans on most.
    let base = Scenario::builder("fi-baseline")
        .system({
            let mut c = SystemConfig::tiny();
            c.workload.seed = 99;
            c
        })
        .traffic(TrafficSpec {
            queries: 400,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Utilization(0.7),
            ..TrafficSpec::default()
        })
        .workers(1)
        .build()
        .unwrap();
    let baseline = scenario::run(&base).unwrap();
    let victim = (0..3)
        .max_by(|&a, &b| {
            selection_mass(&baseline, a)
                .partial_cmp(&selection_mass(&baseline, b))
                .unwrap()
        })
        .unwrap();
    assert!(selection_mass(&baseline, victim) > 0.0);

    // Outage covering the whole run: the selected set must never
    // contain the down expert, and every skip must be counted.
    let full = chaos_serve(
        400,
        ChaosSpec {
            seed: 3,
            expert_outages: vec![ExpertOutage {
                expert: victim,
                down_at: Dur::Seconds(1e-12), // before the first round
                up_at: Dur::Rounds(1e9),
            }],
            ..ChaosSpec::default()
        },
    );
    let r = scenario::run(&full).unwrap();
    assert_eq!(
        selection_mass(&r, victim),
        0.0,
        "down expert {victim} was selected during its outage"
    );
    let c = r.chaos().unwrap();
    assert!(c.forced_exclusions > 0, "exclusions must be counted");
    conserve(&r);

    // Outage covering only the first few rounds: after recovery the
    // expert must come back into rotation.
    let brief = chaos_serve(
        400,
        ChaosSpec {
            seed: 3,
            expert_outages: vec![ExpertOutage {
                expert: victim,
                down_at: Dur::Seconds(1e-12),
                up_at: Dur::Rounds(4.0),
            }],
            ..ChaosSpec::default()
        },
    );
    let r = scenario::run(&brief).unwrap();
    assert!(
        selection_mass(&r, victim) > 0.0,
        "expert {victim} never recovered after its outage window closed"
    );
    conserve(&r);
}

#[test]
fn lossy_links_retry_fail_and_conserve() {
    let s = chaos_serve(
        400,
        ChaosSpec {
            seed: 5,
            link: Some(LinkFaultSpec {
                fail_prob: 0.3,
                max_retries: 1,
                backoff: Dur::Rounds(0.25),
            }),
            ..ChaosSpec::default()
        },
    );
    let r = scenario::run(&s).unwrap();
    let c = r.chaos().unwrap();
    assert!(c.retries > 0, "a 30% loss rate must force retries");
    assert!(c.failed > 0, "some query must exhaust one retry");
    assert_eq!(r.failed(), c.failed);
    assert!(r.availability() < 1.0);
    conserve(&r);
}

#[test]
fn crashed_cell_queries_land_elsewhere_or_shed_never_vanish() {
    let s = chaos_fleet(
        500,
        ChaosSpec {
            seed: 13,
            cell_crashes: vec![(1, Dur::Rounds(10.0))],
            ..ChaosSpec::default()
        },
    );
    let r = scenario::run(&s).unwrap();
    let c = r.chaos().unwrap();
    assert_eq!(c.crashed_cells, 1);
    assert_eq!(r.failed(), 0, "crashes re-route; only link faults fail");
    assert!(r.completed() > 0, "surviving cell must keep completing");
    conserve(&r);
}

#[test]
fn randomized_chaos_schedules_always_conserve() {
    use dmoe::util::rng::Xoshiro256pp;
    let mut rng = Xoshiro256pp::seed_from_u64(0xC4405);
    for trial in 0..3u64 {
        let down = 0.5 + (rng.next_u64() % 20) as f64;
        let up = down + 2.0 + (rng.next_u64() % 30) as f64;
        let expert = (rng.next_u64() % 3) as usize;
        let fail_prob = 0.05 + 0.3 * (rng.next_u64() % 1000) as f64 / 1000.0;
        let chaos = ChaosSpec {
            seed: 100 + trial,
            expert_outages: vec![ExpertOutage {
                expert,
                down_at: Dur::Rounds(down),
                up_at: Dur::Rounds(up),
            }],
            link: Some(LinkFaultSpec {
                fail_prob,
                max_retries: (rng.next_u64() % 3) as usize,
                backoff: Dur::Rounds(0.25),
            }),
            ..ChaosSpec::default()
        };
        let s = chaos_serve(250, chaos.clone());
        let a = scenario::run(&s).unwrap();
        conserve(&a);
        let b = scenario::run(&s).unwrap();
        assert_eq!(
            a.digest(),
            b.digest(),
            "trial {trial} ({chaos:?}) not reproducible"
        );
    }
}

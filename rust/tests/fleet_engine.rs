//! End-to-end coverage of the fleet subsystem: conservation and
//! determinism across cells, throughput scaling at fixed per-cell
//! utilization, cross-cell cache hits, the drain lifecycle, and
//! mobility-driven handover accounting.

use dmoe::coordinator::ServePolicy;
use dmoe::fleet::{
    CellLayout, FleetEngine, FleetOptions, FleetReport, Mobility, MobilityConfig, RoutePolicy,
};
use dmoe::serve::{estimate_round_latency_s, QueueConfig, TrafficConfig};
use dmoe::SystemConfig;

fn tiny_setup(cells: usize, route: RoutePolicy) -> (SystemConfig, FleetOptions) {
    let mut cfg = SystemConfig::tiny(); // K=3, L=2, M=12
    cfg.workload.seed = 99;
    let policy = ServePolicy::jesa(0.8, 2, cfg.moe.layers);
    let queue = QueueConfig::for_system(cfg.moe.experts, 1.0);
    let mut fopts = FleetOptions::new(cells, route, policy, queue);
    fopts.workers = 1;
    fopts.mobility.users = 24;
    (cfg, fopts)
}

fn tiny_traffic(queries: usize, rate_qps: f64) -> TrafficConfig {
    TrafficConfig {
        queries,
        // Few domains + noise-free templates: canonical rounds repeat, so
        // the cache assertions below are statistically safe.
        domains: 4,
        tokens_per_query: 2,
        seed: 7,
        ..TrafficConfig::poisson(rate_qps, queries)
    }
}

fn run(cells: usize, route: RoutePolicy, queries: usize, rate_qps: f64) -> FleetReport {
    let (cfg, fopts) = tiny_setup(cells, route);
    FleetEngine::new(&cfg, fopts).run(&tiny_traffic(queries, rate_qps))
}

#[test]
fn conserves_queries_across_cells() {
    for route in [
        RoutePolicy::RoundRobin,
        RoutePolicy::JoinShortestQueue,
        RoutePolicy::ChannelAware,
    ] {
        let report = run(3, route, 300, 10.0);
        assert_eq!(report.generated, 300, "{}", route.label());
        assert_eq!(
            report.completed + report.shed(),
            report.generated,
            "conservation under {}",
            route.label()
        );
        let routed: usize = report.cells.iter().map(|c| c.routed).sum();
        assert_eq!(routed, report.generated, "every query routed exactly once");
        let done: usize = report.cells.iter().map(|c| c.completed).sum();
        assert_eq!(done, report.completed);
        assert!(report.rounds > 0);
        for c in &report.completions {
            assert!(c.start_s >= c.arrival_s - 1e-12, "started before arrival");
            assert!(c.done_s > c.start_s, "round must take time");
        }
        // Round-robin spreads arrivals evenly by construction.
        if route == RoutePolicy::RoundRobin {
            let max = report.cells.iter().map(|c| c.routed).max().unwrap();
            let min = report.cells.iter().map(|c| c.routed).min().unwrap();
            assert!(max - min <= 1, "rr routed spread {min}..{max}");
        }
    }
}

#[test]
fn deterministic_given_seed() {
    let a = run(2, RoutePolicy::JoinShortestQueue, 300, 10.0);
    let b = run(2, RoutePolicy::JoinShortestQueue, 300, 10.0);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.shed(), b.shed());
    assert_eq!(a.handovers, b.handovers);
    assert_eq!(a.energy.total_j().to_bits(), b.energy.total_j().to_bits());
    assert_eq!(a.cache.hits, b.cache.hits);
    assert_eq!(a.cache.cross_hits, b.cache.cross_hits);
    for (x, y) in a.cells.iter().zip(b.cells.iter()) {
        assert_eq!(x.routed, y.routed);
        assert_eq!(x.completed, y.completed);
        assert_eq!(x.energy.total_j().to_bits(), y.energy.total_j().to_bits());
    }
    for (x, y) in a.completions.iter().zip(b.completions.iter()) {
        assert_eq!(x.done_s.to_bits(), y.done_s.to_bits());
    }
}

#[test]
fn recurring_regimes_hit_across_cells() {
    let report = run(2, RoutePolicy::JoinShortestQueue, 400, 20.0);
    assert!(report.cache.hits > 0, "{:?}", report.cache);
    assert!(
        report.cache.cross_hits > 0,
        "noise-free domain templates must recur across cells: {:?}",
        report.cache
    );
}

#[test]
fn throughput_scales_with_cells_at_fixed_per_cell_utilization() {
    let (cfg, _) = tiny_setup(1, RoutePolicy::JoinShortestQueue);
    let policy = ServePolicy::jesa(0.8, 2, cfg.moe.layers);
    let probe_traffic = tiny_traffic(100, 1.0);
    let mobility = MobilityConfig {
        users: 24,
        ..MobilityConfig::default()
    };
    let mut qps = Vec::new();
    for cells in [1usize, 2] {
        let layout = CellLayout::grid(cells, 200.0);
        let scale =
            Mobility::new(mobility.clone(), &layout).mean_attachment_attenuation(&layout);
        let round_s =
            estimate_round_latency_s(&cfg, &policy, &probe_traffic, 3, scale).max(1e-9);
        let rate = cells as f64 * 0.6 * cfg.moe.experts as f64 / round_s;
        let report = run(cells, RoutePolicy::JoinShortestQueue, 400 * cells, rate);
        assert!(
            report.shed_rate() < 0.2,
            "{cells}-cell run must stay mostly stable at 60% utilization: {:.1}% shed",
            report.shed_rate() * 100.0
        );
        qps.push(report.throughput_qps());
    }
    let speedup = qps[1] / qps[0].max(1e-9);
    assert!(
        speedup >= 1.5,
        "2 cells must scale throughput (got {speedup:.2}x: {:.2} -> {:.2} q/s)",
        qps[0],
        qps[1]
    );
}

#[test]
fn drained_cell_stops_taking_traffic_but_finishes_backlog() {
    let (cfg, mut fopts) = tiny_setup(2, RoutePolicy::RoundRobin);
    // Queries span ~30 s at 10 q/s; drain cell 0 a third of the way in.
    fopts.drain_at.push((0, 10.0));
    let report = FleetEngine::new(&cfg, fopts).run(&tiny_traffic(300, 10.0));
    assert_eq!(report.completed + report.shed(), report.generated);
    let (c0, c1) = (&report.cells[0], &report.cells[1]);
    assert_eq!(c0.state, "drained", "drained cell must finish its backlog");
    assert!(
        c0.routed < c1.routed,
        "post-drain traffic must all go to cell 1 ({} vs {})",
        c0.routed,
        c1.routed
    );
    assert!(c1.state == "active" || c1.state == "warming");
    // Round-robin over the remaining pool serves everything else.
    assert!(c0.completed > 0 && c1.completed > 0);
}

#[test]
fn mobile_users_hand_over_mid_session() {
    let (cfg, mut fopts) = tiny_setup(2, RoutePolicy::ChannelAware);
    // Brisk pedestrians crossing a 2-cell site over a ~40 s stream.
    fopts.mobility.mean_speed_mps = 12.0;
    let report = FleetEngine::new(&cfg, fopts).run(&tiny_traffic(600, 15.0));
    assert!(
        report.continued_sessions > 100,
        "24 users x 600 queries must continue sessions: {}",
        report.continued_sessions
    );
    assert!(
        report.handovers > 0,
        "users moving at 12 m/s must change attachment mid-session"
    );
    assert!(report.handover_rate() > 0.0 && report.handover_rate() < 1.0);
    // The render path covers every aggregate without panicking.
    let text = report.render();
    assert!(text.contains("handover rate"));
    assert!(text.contains("cell  state"));
}

/// The lane-parallel determinism contract: everything in the report
/// digest (completions, energies, per-cell accounting, handovers) is
/// bit-identical between execution modes. Cache *hit* counters are the
/// one commutative exception — racing lanes may solve a fresh key twice
/// (both solves bit-identical) instead of hit-after-miss — so those are
/// checked as inequalities.
fn assert_parallel_matches_sequential(seq: &FleetReport, par: &FleetReport) {
    assert_eq!(seq.digest(), par.digest(), "report digest diverged");
    assert_eq!(seq.generated, par.generated);
    assert_eq!(seq.completed, par.completed);
    assert_eq!(seq.shed(), par.shed());
    assert_eq!(seq.handovers, par.handovers);
    assert_eq!(seq.rounds, par.rounds);
    assert_eq!(
        seq.energy.total_j().to_bits(),
        par.energy.total_j().to_bits()
    );
    for (a, b) in seq.cells.iter().zip(par.cells.iter()) {
        assert_eq!(a.routed, b.routed, "cell {}", a.id);
        assert_eq!(a.completed, b.completed, "cell {}", a.id);
        assert_eq!(a.rounds, b.rounds, "cell {}", a.id);
        assert_eq!(a.state, b.state, "cell {}", a.id);
        assert_eq!(
            a.energy.total_j().to_bits(),
            b.energy.total_j().to_bits(),
            "cell {}",
            a.id
        );
        assert_eq!(a.latency_p99_s.to_bits(), b.latency_p99_s.to_bits());
    }
    for (a, b) in seq.completions.iter().zip(par.completions.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.done_s.to_bits(), b.done_s.to_bits());
    }
    // Cache: one lookup per layer solve in both modes; racing double
    // misses can only convert hits into misses (never invent hits), and
    // re-inserting the same key leaves the entry count unchanged.
    assert_eq!(seq.cache.lookups(), par.cache.lookups());
    assert!(par.cache.hits <= seq.cache.hits);
    assert_eq!(seq.cache.entries, par.cache.entries);
}

#[test]
fn parallel_lanes_match_sequential_bit_identically() {
    // Every route policy: rr exercises the fully lane-parallel replay,
    // jsq/channel the lockstep path with executor-dispatched due cells.
    for route in [
        RoutePolicy::RoundRobin,
        RoutePolicy::JoinShortestQueue,
        RoutePolicy::ChannelAware,
    ] {
        let traffic = tiny_traffic(400, 15.0);
        let (cfg, seq_opts) = tiny_setup(3, route);
        let mut par_opts = seq_opts.clone();
        par_opts.lane_workers = 3;
        par_opts.cache_shards = 4;
        let seq = FleetEngine::new(&cfg, seq_opts).run(&traffic);
        let par = FleetEngine::new(&cfg, par_opts).run(&traffic);
        assert_parallel_matches_sequential(&seq, &par);
    }
}

#[test]
fn parallel_run_is_deterministic_across_repeats() {
    let traffic = tiny_traffic(300, 12.0);
    let (cfg, mut fopts) = tiny_setup(3, RoutePolicy::RoundRobin);
    fopts.lane_workers = 3;
    let a = FleetEngine::new(&cfg, fopts.clone()).run(&traffic);
    let b = FleetEngine::new(&cfg, fopts).run(&traffic);
    assert_eq!(a.digest(), b.digest(), "parallel runs must be reproducible");
}

#[test]
fn scheduled_drain_forces_lockstep_and_stays_bit_identical() {
    // A drain makes round-robin routing execution-dependent (the
    // Drained transition reads queue state), so the engine must fall
    // back to the lockstep path — and still match sequentially.
    let traffic = tiny_traffic(300, 10.0);
    let (cfg, mut seq_opts) = tiny_setup(2, RoutePolicy::RoundRobin);
    seq_opts.drain_at.push((0, 10.0));
    let mut par_opts = seq_opts.clone();
    par_opts.lane_workers = 2;
    let seq = FleetEngine::new(&cfg, seq_opts).run(&traffic);
    let par = FleetEngine::new(&cfg, par_opts).run(&traffic);
    assert_parallel_matches_sequential(&seq, &par);
    assert_eq!(par.cells[0].state, "drained");
}

#[test]
fn sharded_cache_still_hits_across_cells() {
    let traffic = tiny_traffic(400, 20.0);
    let (cfg, mut fopts) = tiny_setup(2, RoutePolicy::JoinShortestQueue);
    fopts.lane_workers = 2;
    fopts.cache_shards = 8;
    let report = FleetEngine::new(&cfg, fopts).run(&traffic);
    assert!(report.cache.hits > 0, "{:?}", report.cache);
    assert!(
        report.cache.cross_hits > 0,
        "noise-free domain templates must recur across cells: {:?}",
        report.cache
    );
}

#[test]
fn route_policy_parsing() {
    assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
    assert_eq!(
        RoutePolicy::parse("jsq"),
        Some(RoutePolicy::JoinShortestQueue)
    );
    assert_eq!(
        RoutePolicy::parse("channel-aware"),
        Some(RoutePolicy::ChannelAware)
    );
    assert_eq!(RoutePolicy::parse("nope"), None);
    assert_eq!(RoutePolicy::RoundRobin.label(), "round-robin");
}

#[test]
fn single_cell_fleet_behaves_like_one_lane() {
    // A 1-cell fleet is a degenerate sharding: everything routes to cell
    // 0, rounds never overlap, and the fleet aggregates reduce to the
    // cell's own numbers.
    let report = run(1, RoutePolicy::ChannelAware, 200, 8.0);
    assert_eq!(report.cells.len(), 1);
    assert_eq!(report.cells[0].routed, report.generated);
    assert_eq!(report.cells[0].completed, report.completed);
    assert!((report.imbalance() - 1.0).abs() < 1e-12);
    assert!((report.jain_index() - 1.0).abs() < 1e-12);
    // Serial lane: completions ordered by round start never overlap.
    let mut sorted = report.completions.clone();
    sorted.sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap());
    for w in sorted.windows(2) {
        assert!(
            w[1].start_s >= w[0].done_s - 1e-9 || w[1].start_s == w[0].start_s,
            "rounds overlap in a single-lane fleet"
        );
    }
}

//! Property-based invariant tests (in-tree generator loops; the
//! environment vendors no proptest, so we drive randomized cases from the
//! deterministic xoshiro PRNG — failures print the seed for replay).

use dmoe::assignment::hungarian_min_cost;
use dmoe::channel::{ChannelModel, ChannelState};
use dmoe::config::{ChannelConfig, EnergyConfig, SystemConfig};
use dmoe::energy::EnergyModel;
use dmoe::gating::{GateScores, SyntheticGate};
use dmoe::jesa::{solve_round, AllocationMode, JesaOptions, RoundProblem, SelectionPolicy};
use dmoe::selection::{des, exhaustive, greedy, topk, SelectionProblem};
use dmoe::util::json::Json;
use dmoe::util::rng::Xoshiro256pp;

fn random_problem(rng: &mut Xoshiro256pp, k: usize, d: usize, structured: bool) -> SelectionProblem {
    let scores: Vec<f64> = if structured && rng.next_f64() < 0.3 {
        // Spiky: one dominant expert (common with a trained gate).
        let hot = rng.range_usize(0, k);
        (0..k)
            .map(|j| if j == hot { 10.0 } else { rng.next_f64() })
            .collect()
    } else {
        (0..k).map(|_| rng.next_f64_open()).collect()
    };
    let sum: f64 = scores.iter().sum();
    let scores: Vec<f64> = scores.iter().map(|x| x / sum).collect();
    let costs: Vec<f64> = (0..k)
        .map(|_| {
            if structured && rng.next_f64() < 0.15 {
                f64::INFINITY // starved link
            } else if structured && rng.next_f64() < 0.1 {
                0.0 // free in-situ-like expert
            } else {
                rng.next_f64_open() * 5.0
            }
        })
        .collect();
    let threshold = rng.next_f64();
    SelectionProblem::new(scores, costs, threshold, d)
}

/// DES == exhaustive on structured instances (ties, spikes, inf costs).
#[test]
fn prop_des_optimal_on_structured_instances() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED_0001);
    for trial in 0..400 {
        let k = rng.range_usize(1, 13);
        let d = rng.range_usize(1, k + 1);
        let p = random_problem(&mut rng, k, d, true);
        let (a, _) = des::solve(&p);
        let b = exhaustive::solve(&p);
        assert_eq!(a.fallback, b.fallback, "trial {trial}: {p:?}");
        if a.cost.is_finite() || b.cost.is_finite() {
            assert!(
                (a.cost - b.cost).abs() < 1e-9,
                "trial {trial}: DES {} != oracle {} on {p:?}",
                a.cost,
                b.cost
            );
        }
    }
}

/// Every algorithm returns structurally valid selections.
#[test]
fn prop_selection_outputs_valid() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED_0002);
    for _ in 0..300 {
        let k = rng.range_usize(1, 16);
        let d = rng.range_usize(1, k + 1);
        let p = random_problem(&mut rng, k, d, true);
        for sel in [
            des::solve(&p).0,
            greedy::solve(&p),
            topk::solve(&p, d),
            exhaustive::solve(&p),
        ] {
            assert!(sel.selected.len() <= k);
            assert!(sel.selected.windows(2).all(|w| w[0] < w[1]), "sorted unique");
            assert!(sel.selected.iter().all(|&j| j < k));
            let score: f64 = sel.selected.iter().map(|&j| p.scores[j]).sum();
            assert!((score - sel.score).abs() < 1e-9);
        }
    }
}

/// DES never selects an unreachable expert when a feasible finite
/// alternative exists, and its reported cost is exactly the sum.
#[test]
fn prop_des_cost_consistency() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED_0003);
    for _ in 0..300 {
        let k = rng.range_usize(2, 12);
        let d = rng.range_usize(1, k + 1);
        let p = random_problem(&mut rng, k, d, true);
        let (sel, _) = des::solve(&p);
        let cost: f64 = sel.selected.iter().map(|&j| p.costs[j]).sum();
        assert!(
            (cost - sel.cost).abs() < 1e-9 || (cost.is_infinite() && sel.cost.is_infinite())
        );
        if !sel.fallback {
            assert!(sel.cost.is_finite(), "non-fallback selection must be reachable");
            assert!(p.is_feasible(&sel.selected));
        }
    }
}

/// Hungarian matches an independent greedy lower-bound sanity relation:
/// optimal cost >= sum of per-row minima, and <= any greedy assignment.
#[test]
fn prop_hungarian_bounds() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED_0004);
    for _ in 0..200 {
        let n = rng.range_usize(1, 10);
        let m = rng.range_usize(n, n + 10);
        let cost: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..m).map(|_| rng.next_f64() * 50.0).collect())
            .collect();
        let (assign, total) = hungarian_min_cost(&cost).unwrap();
        let row_min_sum: f64 = cost
            .iter()
            .map(|r| r.iter().cloned().fold(f64::INFINITY, f64::min))
            .sum();
        assert!(total >= row_min_sum - 1e-9);
        // Greedy row-by-row with exclusion.
        let mut used = vec![false; m];
        let mut greedy_total = 0.0;
        for r in 0..n {
            let (c, v) = (0..m)
                .filter(|&c| !used[c])
                .map(|c| (c, cost[r][c]))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            used[c] = true;
            greedy_total += v;
        }
        assert!(total <= greedy_total + 1e-9, "optimal beat by greedy");
        // Permutation validity.
        let mut seen = std::collections::HashSet::new();
        for c in assign {
            assert!(seen.insert(c));
        }
    }
}

/// JESA invariants across random rounds: exclusivity, C1/C2 (modulo
/// fallbacks), finite energy, monotone vs iteration budget.
#[test]
fn prop_jesa_round_invariants() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED_0005);
    for trial in 0..40 {
        let k = rng.range_usize(2, 6);
        let m = rng.range_usize(k * (k - 1), 4 * k * k);
        let tokens = rng.range_usize(1, 5);
        let threshold = rng.next_f64() * 0.8;
        let d = rng.range_usize(1, k + 1);

        let ch_cfg = ChannelConfig {
            subcarriers: m,
            ..ChannelConfig::default()
        };
        let mut ch = ChannelModel::new(ch_cfg.clone(), k, 0xAA00 + trial);
        let state = ch.realize();
        let gate = SyntheticGate::new(k, 1.0);
        let gates: Vec<Vec<GateScores>> = (0..k)
            .map(|_| (0..tokens).map(|_| gate.sample(&mut rng)).collect())
            .collect();
        let problem = RoundProblem {
            gates,
            threshold,
            max_active: d,
        };
        let energy = EnergyModel::new(ch_cfg, EnergyConfig::paper(k, 1024.0));
        let sol = solve_round(&state, &problem, &energy, &JesaOptions::default());

        assert!(sol.allocation.is_exclusive(), "trial {trial}: C3 violated");
        assert!(sol.energy.total_j().is_finite() && sol.energy.total_j() >= 0.0);
        for (i, row) in sol.selections.iter().enumerate() {
            for (n, sel) in row.iter().enumerate() {
                assert!(sel.selected.len() <= d, "trial {trial}: C2 violated");
                if !sel.fallback {
                    let score: f64 = sel
                        .selected
                        .iter()
                        .map(|&j| problem.gates[i][n].score(j))
                        .sum();
                    assert!(
                        score >= threshold - 1e-9,
                        "trial {trial}: C1 violated at ({i},{n})"
                    );
                }
            }
        }
    }
}

/// Energy ordering across policies holds on random instances:
/// LB <= JESA <= Top-D (within tolerance, same instance).
#[test]
fn prop_policy_energy_ordering() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED_0006);
    for trial in 0..20 {
        let k = 4;
        let ch_cfg = ChannelConfig {
            subcarriers: 32,
            ..ChannelConfig::default()
        };
        let mut ch = ChannelModel::new(ch_cfg.clone(), k, 0xBB00 + trial);
        let state = ch.realize();
        let gate = SyntheticGate::new(k, 1.0);
        let gates: Vec<Vec<GateScores>> = (0..k)
            .map(|_| (0..3).map(|_| gate.sample(&mut rng)).collect())
            .collect();
        let problem = RoundProblem {
            gates,
            threshold: 0.5,
            max_active: 2,
        };
        let energy = EnergyModel::new(ch_cfg, EnergyConfig::paper(k, 8192.0));
        let run = |policy, allocation| {
            solve_round(
                &state,
                &problem,
                &energy,
                &JesaOptions {
                    policy,
                    allocation,
                    ..JesaOptions::default()
                },
            )
            .energy
            .total_j()
        };
        let jesa = run(SelectionPolicy::Des, AllocationMode::Exclusive);
        let lb = run(SelectionPolicy::Des, AllocationMode::LowerBound);
        let top = run(SelectionPolicy::TopK(2), AllocationMode::Exclusive);
        assert!(lb <= jesa + 1e-9, "trial {trial}: LB {lb} > JESA {jesa}");
        assert!(jesa <= top + 1e-9, "trial {trial}: JESA {jesa} > Top-2 {top}");
    }
}

/// JSON fuzz: parser never panics on mangled valid documents and
/// round-trips whatever it accepts.
#[test]
fn prop_json_fuzz_roundtrip() {
    let base = r#"{"a":[1,2.5,"s",false,null],"b":{"c":-3e2,"d":"é"}}"#;
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED_0007);
    for _ in 0..2000 {
        let mut bytes = base.as_bytes().to_vec();
        let flips = rng.range_usize(0, 4);
        for _ in 0..flips {
            let i = rng.range_usize(0, bytes.len());
            bytes[i] = (rng.next_below(94) + 32) as u8;
        }
        if let Ok(text) = std::str::from_utf8(&bytes) {
            if let Ok(v) = Json::parse(text) {
                let v2 = Json::parse(&v.to_string()).expect("reserialized must parse");
                assert_eq!(v, v2);
            }
        }
    }
}

/// Channel realizations stay physical under extreme configs.
#[test]
fn prop_channel_physical() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED_0008);
    for _ in 0..20 {
        let cfg = ChannelConfig {
            b0_hz: rng.range_f64(1e3, 1e8),
            p0_w: rng.range_f64(1e-6, 1.0),
            snr_db: rng.range_f64(-10.0, 40.0),
            subcarriers: rng.range_usize(1, 64),
            path_loss: rng.range_f64(1e-6, 1.0),
        };
        let k = rng.range_usize(1, 5);
        let mut ch = ChannelModel::new(cfg, k, rng.next_u64());
        let st: ChannelState = ch.realize();
        for i in 0..k {
            for j in 0..k {
                for m in 0..st.subcarriers() {
                    let r = st.rate(i, j, m);
                    if i == j {
                        assert!(r.is_infinite());
                    } else {
                        assert!(r > 0.0 && r.is_finite());
                        assert!(st.gain(i, j, m) >= 0.0);
                    }
                }
            }
        }
    }
}

/// System config round-trips through JSON for random valid settings.
#[test]
fn prop_config_roundtrip() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED_0009);
    for _ in 0..100 {
        let mut cfg = SystemConfig::default();
        cfg.moe.experts = rng.range_usize(1, 16);
        cfg.moe.layers = rng.range_usize(1, 40);
        cfg.moe.max_active = rng.range_usize(1, cfg.moe.experts + 1);
        cfg.energy = EnergyConfig::paper(cfg.moe.experts, rng.range_f64(1.0, 1e5));
        cfg.selection.z = rng.next_f64();
        cfg.selection.gamma0 = rng.next_f64();
        cfg.channel.subcarriers = rng.range_usize(1, 2048);
        cfg.workload.seed = rng.next_u64() >> 12;
        cfg.validate().unwrap();
        let text = cfg.to_json().to_string_pretty();
        let back = SystemConfig::from_json_str(&text).unwrap();
        assert_eq!(cfg, back);
    }
}

//! End-to-end integration tests over the real AOT artifacts.
//!
//! These need `make artifacts` to have run (they are skipped with a
//! message otherwise, so `cargo test` stays green on a fresh checkout).
//! The parity test replays the Python-recorded selection masks through
//! the Rust PJRT pipeline and asserts the logits match `forward_select`
//! to float tolerance — proving L1 (Pallas), L2 (JAX blocks) and L3
//! (aggregation, routing) compose identically across the language
//! boundary.

use dmoe::coordinator::{DmoeServer, ServePolicy};
use dmoe::runtime::{Matrix, ModelRuntime};
use dmoe::util::json::Json;
use dmoe::workload::{load_eval_sets, Query};
use dmoe::SystemConfig;

fn artifacts_dir() -> Option<String> {
    if !dmoe::runtime::pjrt_available() {
        eprintln!("skipping: built without the `xla` feature (no PJRT runtime)");
        return None;
    }
    let dir = std::env::var("DMOE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {dir}/manifest.json (run `make artifacts`)");
        None
    }
}

fn load_runtime() -> Option<ModelRuntime> {
    artifacts_dir().map(|d| ModelRuntime::load(&d).expect("artifacts must load"))
}

#[test]
fn blocks_load_and_execute() {
    let Some(rt) = load_runtime() else { return };
    let meta = rt.manifest.model.clone();
    let tokens: Vec<i32> = (0..meta.seq_len as i32).collect();
    let h = rt.embed(&tokens).unwrap();
    assert_eq!((h.rows(), h.cols()), (meta.seq_len, meta.d_model));

    let h1 = rt.attn(0, &h).unwrap();
    assert_eq!((h1.rows(), h1.cols()), (meta.seq_len, meta.d_model));
    // Residual block must change the stream.
    assert!(h1.max_abs_diff(&h) > 0.0);

    let g = rt.gate(0, &h1).unwrap();
    assert_eq!((g.rows(), g.cols()), (meta.seq_len, meta.experts));
    for t in 0..g.rows() {
        let sum: f32 = g.row(t).iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "gate row {t} sums to {sum}");
        assert!(g.row(t).iter().all(|&x| x >= 0.0));
    }

    let f = rt.ffn(0, 0, &h1).unwrap();
    assert_eq!((f.rows(), f.cols()), (meta.seq_len, meta.d_model));

    let logits = rt.head(&h1).unwrap();
    assert_eq!((logits.rows(), logits.cols()), (meta.seq_len, meta.vocab));
}

#[test]
fn parity_with_jax_forward_select() {
    let Some(rt) = load_runtime() else { return };
    let meta = rt.manifest.model.clone();
    let parity_file = rt.manifest.parity.clone().expect("manifest lists parity fixture");
    let text = std::fs::read_to_string(rt.manifest.path(&parity_file)).unwrap();
    let v = Json::parse(&text).unwrap();

    let tokens: Vec<i32> = v
        .get("tokens")
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect();
    // masks[l][t][k] in {0,1}
    let masks = v.get("masks").as_arr().unwrap();
    let expected_rows = v.get("logits").as_arr().unwrap();

    // Replay: embed -> per layer (attn, gate, masked eq.-8 aggregation) -> head.
    let mut h = rt.embed(&tokens).unwrap();
    for l in 0..meta.layers {
        h = rt.attn(l, &h).unwrap();
        let g = rt.gate(l, &h).unwrap();
        let layer_mask = &masks[l];
        // All experts process the full block (parity fixture routes every
        // token somewhere; running all experts is fine for parity).
        let outs: Vec<Matrix> = (0..meta.experts)
            .map(|j| rt.ffn(l, j, &h).unwrap())
            .collect();
        let mut agg = h.clone();
        for t in 0..meta.seq_len {
            let row_mask = layer_mask.at(t);
            let selected: Vec<usize> = (0..meta.experts)
                .filter(|&j| row_mask.at(j).as_f64().unwrap_or(0.0) > 0.5)
                .collect();
            if selected.is_empty() {
                continue;
            }
            let gsum: f32 = selected.iter().map(|&j| g.get(t, j)).sum();
            for &j in &selected {
                let w = g.get(t, j) / gsum.max(1e-12);
                agg.add_scaled_row(t, &outs[j], t, w);
            }
        }
        h = agg;
    }
    let logits = rt.head(&h).unwrap();

    let mut max_diff = 0.0f64;
    for t in 0..meta.seq_len {
        let row = expected_rows[t].as_arr().unwrap();
        for c in 0..meta.vocab {
            let e = row[c].as_f64().unwrap();
            max_diff = max_diff.max((logits.get(t, c) as f64 - e).abs());
        }
    }
    assert!(
        max_diff < 2e-3,
        "rust pipeline diverges from jax forward_select: max |Δlogit| = {max_diff}"
    );
    println!("parity OK: max |Δlogit| = {max_diff:.2e}");
}

#[test]
fn serve_batch_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = SystemConfig::default();
    cfg.artifacts_dir = dir;
    let mut server = DmoeServer::new(&cfg).unwrap();
    let layers = server.layers();

    let eval_sets = load_eval_sets(&server.runtime().manifest).unwrap();
    assert_eq!(eval_sets.len(), 5, "five benchmark analogues expected");

    let policy = ServePolicy::jesa(0.8, 2, layers);
    let result = server
        .serve_eval_set(&eval_sets[0], &policy, Some(2))
        .unwrap();
    assert!(result.total > 0);
    assert!(result.accuracy() > 0.0 && result.accuracy() <= 1.0);
    assert!(result.ledger.total().total_j() > 0.0);
    assert!(result.radio_s > 0.0);
    assert!(result.metrics.counter("ffn_exec") > 0);
    // Selection pattern covers every layer.
    for l in 0..layers {
        let any: f64 = (0..server.experts())
            .map(|j| result.pattern.probability(l, j))
            .sum();
        assert!(any > 0.0, "no selections recorded at layer {l}");
    }
}

#[test]
fn forced_single_expert_matches_width_one() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = SystemConfig::default();
    cfg.artifacts_dir = dir;
    let mut server = DmoeServer::new(&cfg).unwrap();
    let layers = server.layers();
    let seq = server.runtime().seq_len();

    let q = Query {
        id: 0,
        source_expert: 0,
        tokens: (0..seq as i32).collect(),
        labels: (1..=seq as i32).collect(),
        domain: 0,
    };
    let result = server
        .serve_batch(&[q], &ServePolicy::forced(1, layers))
        .unwrap();
    // Forced(1): every token selects exactly expert 1 at every layer.
    for l in 0..layers {
        assert!((result.pattern.probability(l, 1) - 1.0).abs() < 1e-12);
        assert_eq!(result.pattern.probability(l, 0), 0.0);
    }
    // All tokens from source 0 to expert 1 are remote.
    assert_eq!(
        result.metrics.counter("remote_tokens"),
        (seq * layers) as u64
    );
}

#[test]
fn des_saves_energy_vs_topk_on_real_model() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = SystemConfig::default();
    cfg.artifacts_dir = dir;
    let mut server = DmoeServer::new(&cfg).unwrap();
    let layers = server.layers();
    let eval_sets = load_eval_sets(&server.runtime().manifest).unwrap();

    let des = server
        .serve_eval_set(&eval_sets[0], &ServePolicy::jesa(0.7, 2, layers), Some(2))
        .unwrap();
    let topk = server
        .serve_eval_set(&eval_sets[0], &ServePolicy::topk(2, layers), Some(2))
        .unwrap();
    assert!(
        des.ledger.total().total_j() < topk.ledger.total().total_j(),
        "DES ({} J) should beat Top-2 ({} J)",
        des.ledger.total().total_j(),
        topk.ledger.total().total_j()
    );
}

#[test]
fn node_churn_reroutes_around_offline_expert() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = SystemConfig::default();
    cfg.artifacts_dir = dir;
    let mut server = DmoeServer::new(&cfg).unwrap();
    let layers = server.layers();
    let seq = server.runtime().seq_len();
    let mk = |src| Query {
        id: src as u64,
        source_expert: src,
        tokens: (0..seq as i32).collect(),
        labels: (1..=seq as i32).collect(),
        domain: 0,
    };
    let policy = ServePolicy::jesa(0.8, 2, layers);

    // Expert 2 leaves the ad-hoc system (paper §VIII extension).
    server.set_expert_online(2, false);
    assert!(!server.is_expert_online(2));

    // Queries can no longer be assigned to it…
    assert!(server.serve_batch(&[mk(2)], &policy).is_err());

    // …and serving from another source never routes tokens to it.
    let r = server.serve_batch(&[mk(0)], &policy).unwrap();
    for l in 0..layers {
        assert_eq!(
            r.pattern.probability(l, 2),
            0.0,
            "offline expert selected at layer {l}"
        );
    }
    assert!(r.total > 0);

    // Rejoin: selections may include it again.
    server.set_expert_online(2, true);
    let r2 = server.serve_batch(&[mk(0)], &policy).unwrap();
    assert!(r2.total > 0);
}

//! End-to-end coverage of the scenario front door: bit-identical JSON
//! round-trips for every preset, file-load + run digest determinism,
//! the unified `Engine` trait over both engine shapes, streaming
//! `EngineObserver` delivery, and the selector-registry plumbing.

use dmoe::fleet::{MobilityConfig, RoutePolicy};
use dmoe::scenario::{
    self, CountingObserver, EngineKind, FleetSpec, PolicySpec, QuantSpec, RateSpec, RunReport,
    Scenario, TrafficSpec, PRESET_NAMES,
};
use dmoe::selection::SelectorSpec;
use dmoe::SystemConfig;

fn tiny_serve(queries: usize) -> Scenario {
    let mut cfg = SystemConfig::tiny(); // K=3, L=2, M=12
    cfg.workload.seed = 99;
    Scenario::builder("tiny-serve")
        .system(cfg)
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Utilization(0.7),
            ..TrafficSpec::default()
        })
        .workers(1)
        .build()
        .unwrap()
}

/// Mirrors the proven `fleet_engine.rs` mobility setup (24 brisk
/// pedestrians on a 2-cell site, ~40 s stream at 600 queries) so the
/// handover assertions below are statistically safe.
fn tiny_fleet(queries: usize) -> Scenario {
    let mut cfg = SystemConfig::tiny();
    cfg.workload.seed = 99;
    Scenario::builder("tiny-fleet")
        .system(cfg)
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Qps(15.0),
            ..TrafficSpec::default()
        })
        .workers(1)
        .fleet(FleetSpec {
            cells: 2,
            route: RoutePolicy::JoinShortestQueue,
            mobility: MobilityConfig {
                users: 24,
                mean_speed_mps: 12.0,
                ..MobilityConfig::default()
            },
            lane_workers: Some(0),
            ..FleetSpec::default()
        })
        .build()
        .unwrap()
}

// -- JSON round-trip property over the whole preset library -----------------

#[test]
fn every_preset_roundtrips_through_json_bit_identically() {
    for name in PRESET_NAMES {
        let s = Scenario::preset(name).unwrap();
        let j1 = s.to_json().to_string_pretty();
        let back = Scenario::from_json_str(&j1)
            .unwrap_or_else(|e| panic!("preset {name} must re-parse: {e:#}"));
        assert_eq!(back, s, "preset {name}: parse(serialize(s)) != s");
        let j2 = back.to_json().to_string_pretty();
        assert_eq!(j1, j2, "preset {name}: canonical JSON not bit-identical");
        // Compact form round-trips too.
        let compact = s.to_json().to_string();
        let back2 = Scenario::from_json_str(&compact).unwrap();
        assert_eq!(back2, s, "preset {name}: compact round-trip");
    }
}

#[test]
fn hand_built_scenarios_roundtrip_including_optional_sections() {
    for s in [tiny_serve(50), tiny_fleet(50)] {
        let j1 = s.to_json().to_string_pretty();
        let back = Scenario::from_json_str(&j1).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.to_json().to_string_pretty(), j1);
        // Optional sections survive: fleet presence matches.
        assert_eq!(back.fleet.is_some(), s.fleet.is_some());
    }
}

// -- file load + run digest determinism -------------------------------------

#[test]
fn scenario_file_runs_deterministically_for_both_shapes() {
    let dir = std::env::temp_dir().join(format!("dmoe-scenario-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for (tag, s) in [("serve", tiny_serve(200)), ("fleet", tiny_fleet(200))] {
        let path = dir.join(format!("{tag}.json"));
        let path = path.to_str().unwrap();
        s.save(path).unwrap();
        let loaded = Scenario::load(path).unwrap();
        assert_eq!(loaded, s, "{tag}: file round-trip");

        let a = scenario::run(&loaded).unwrap();
        let b = scenario::run(&loaded).unwrap();
        assert_eq!(
            a.digest(),
            b.digest(),
            "{tag}: same scenario file must yield identical report digests"
        );
        // And the file-loaded run matches the in-memory build.
        let c = scenario::run(&s).unwrap();
        assert_eq!(a.digest(), c.digest(), "{tag}: loaded vs built digest");
        assert!(a.completed() > 0, "{tag}: nothing completed");
        assert_eq!(
            a.completed() + a.shed(),
            a.generated(),
            "{tag}: conservation"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

// -- the unified Engine trait -----------------------------------------------

#[test]
fn both_engine_shapes_run_behind_the_engine_trait() {
    let serve = scenario::prepare(&tiny_serve(150)).unwrap();
    let fleet = scenario::prepare(&tiny_fleet(150)).unwrap();
    assert_eq!(serve.kind(), EngineKind::Serve);
    assert_eq!(fleet.kind(), EngineKind::Fleet);
    for prepared in [&serve, &fleet] {
        // Everything below goes through `&dyn Engine` — no engine-type
        // match anywhere.
        let engine = prepared.engine();
        let report = engine.run_report(&prepared.traffic);
        assert_eq!(report.kind(), engine.kind());
        assert_eq!(report.completed() + report.shed(), report.generated());
        assert!(report.rounds() > 0);
        assert!(report.energy().total_j() > 0.0);
        assert!(!report.render().is_empty());
        assert!(!prepared.banner().is_empty());
    }
}

// -- EngineObserver delivery ------------------------------------------------

#[test]
fn serve_observer_streams_rounds_sheds_and_cache() {
    let mut s = tiny_serve(300);
    // Tight deadlines force the shed path so on_shed is exercised.
    s.queue.deadline = Some(scenario::Dur::Seconds(1e-6));
    s.queue.max_wait = Some(scenario::Dur::Seconds(1e-7));
    s.traffic.rate = RateSpec::Qps(1000.0);
    let mut obs = CountingObserver::default();
    let report = scenario::run_observed(&s, &mut obs).unwrap();
    assert_eq!(obs.rounds, report.rounds(), "one RoundEvent per round");
    assert_eq!(obs.sheds, report.shed(), "one ShedEvent per shed query");
    assert!(obs.sheds > 0, "overload scenario must shed");
    assert_eq!(obs.queries, report.completed(), "round events carry batches");
    assert_eq!(obs.cache_reports, 1, "final cache stats exactly once");
    assert_eq!(obs.cache_hits_final, report.cache().hits);
}

#[test]
fn fleet_observer_sees_handovers_rounds_and_sheds() {
    let s = tiny_fleet(600);
    let mut obs = CountingObserver::default();
    let report = scenario::run_observed(&s, &mut obs).unwrap();
    let fleet_report = match &report {
        RunReport::Fleet(r) => r,
        RunReport::Serve(_) => panic!("fleet-shaped scenario ran the serve engine"),
    };
    assert_eq!(
        obs.handovers, fleet_report.handovers,
        "one HandoverEvent per recorded handover"
    );
    assert_eq!(obs.rounds, report.rounds(), "per-cell round replay is complete");
    assert_eq!(obs.sheds, report.shed(), "per-cell shed replay is complete");
    assert_eq!(obs.cache_reports, 1);
    // Vehicular users on a tight 2-cell grid must actually hand over,
    // otherwise this test asserts nothing.
    assert!(
        fleet_report.handovers > 0,
        "expected mobility-driven handovers in this setup"
    );
}

#[test]
fn observer_run_leaves_report_identical_to_plain_run() {
    let s = tiny_serve(200);
    let mut obs = CountingObserver::default();
    let observed = scenario::run_observed(&s, &mut obs).unwrap();
    let plain = scenario::run(&s).unwrap();
    assert_eq!(observed.digest(), plain.digest(), "observation must be passive");
}

// -- selector registry plumbing ---------------------------------------------

#[test]
fn scenario_selector_override_reaches_the_solver() {
    let mut greedy = tiny_serve(150);
    greedy.policy = PolicySpec::jesa(0.8, 2).with_selector(SelectorSpec::Greedy);
    let prepared = scenario::prepare(&greedy).unwrap();
    assert!(
        prepared.banner().contains("greedy"),
        "selector override must show in the policy label: {}",
        prepared.banner()
    );
    let report = prepared.run();
    assert_eq!(report.completed() + report.shed(), report.generated());

    // The overridden scenario stays deterministic end-to-end. (No
    // cross-solver energy comparison here: the two scenarios calibrate
    // different offered rates, so whole-run totals are not comparable —
    // per-instance optimality is covered by the registry unit tests.)
    let again = scenario::run(&greedy).unwrap();
    assert_eq!(report.digest(), again.digest());
}

#[test]
fn selector_roundtrips_in_scenario_json() {
    let mut s = tiny_serve(50);
    s.policy = PolicySpec::homogeneous(0.4, 2).with_selector(SelectorSpec::Dp(128));
    s.validate().unwrap();
    let text = s.to_json().to_string_pretty();
    assert!(text.contains("\"selector\": \"dp:128\""), "{text}");
    let back = Scenario::from_json_str(&text).unwrap();
    assert_eq!(back, s);
}

// -- validation diagnostics -------------------------------------------------

#[test]
fn parse_errors_carry_field_paths() {
    // Unknown top-level key.
    let err = Scenario::from_json_str(r#"{"name": "x", "trafic": {}}"#).unwrap_err();
    assert!(format!("{err:#}").contains("trafic"), "{err:#}");

    // Unknown field inside a section.
    let err =
        Scenario::from_json_str(r#"{"name": "x", "traffic": {"querys": 10}}"#).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("scenario.traffic") && msg.contains("querys"), "{msg}");

    // Bad selector name names the registry's options.
    let err = Scenario::from_json_str(
        r#"{"name": "x", "policy": {"kind": "jesa", "selector": "dse"}}"#,
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("scenario.policy.selector"), "{msg}");
    assert!(msg.contains("des"), "{msg}");

    // Bad route spelling.
    let err = Scenario::from_json_str(
        r#"{"name": "x", "fleet": {"cells": 2, "route": "jqs"}}"#,
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("jqs"), "{err:#}");

    // Cross-field: batch larger than the expert count.
    let err = Scenario::from_json_str(
        r#"{"name": "x", "queue": {"batch_queries": 99}}"#,
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("batch_queries") && msg.contains("99"), "{msg}");

    // Unsupported schema version.
    let err = Scenario::from_json_str(r#"{"name": "x", "schema_version": 99}"#).unwrap_err();
    assert!(format!("{err:#}").contains("schema_version"), "{err:#}");
}

#[test]
fn unknown_preset_error_lists_the_library() {
    let err = Scenario::preset("papper-baseline").unwrap_err();
    let msg = err.to_string();
    for name in PRESET_NAMES {
        assert!(msg.contains(name), "error must list '{name}': {msg}");
    }
}

#[test]
fn duration_and_rate_forms_parse() {
    let s = Scenario::from_json_str(
        r#"{
            "name": "forms",
            "traffic": {
                "process": {"kind": "bursty", "dwell": {"s": 2.5}},
                "rate": {"qps": 12.5}
            },
            "queue": {"max_wait": {"rounds": 2}}
        }"#,
    )
    .unwrap();
    match &s.traffic.process {
        scenario::ProcessSpec::Bursty { dwell } => {
            assert_eq!(dwell.resolve(10.0), 2.5, "absolute seconds ignore round_s")
        }
        other => panic!("expected bursty, got {other:?}"),
    }
    assert_eq!(s.traffic.rate, RateSpec::Qps(12.5));
    assert_eq!(s.queue.max_wait.unwrap().resolve(0.5), 1.0, "2 rounds at 0.5 s");
}

#[test]
fn quant_validation_only_binds_with_a_fixed_grid_cache() {
    let mut s = tiny_serve(50);
    s.quant = QuantSpec {
        adaptive: false,
        log2_step: -1.0,
        gate_levels: 32,
    };
    assert!(s.validate().is_err(), "fixed bad grid must be rejected");
    s.cache.capacity = 0;
    s.validate()
        .expect("cacheless scenarios never touch the quantizer");
}

//! Integration tests for the continuous serving engine: conservation,
//! determinism, cache behaviour under a real multi-round run, and the
//! satellite guarantee that the latency numbers the engine reports match
//! the discrete-event timelines (`protocol::sim`), including
//! `critical_path` on every recorded round.

use dmoe::coordinator::ServePolicy;
use dmoe::protocol::sim::Event;
use dmoe::serve::{
    ArrivalProcess, QuantizerConfig, QueueConfig, ServeEngine, ServeOptions, TrafficConfig,
};
use dmoe::SystemConfig;

fn setup(queries: usize) -> (SystemConfig, ServeOptions, TrafficConfig) {
    let cfg = SystemConfig::tiny(); // K=3, L=2, M=12
    let policy = ServePolicy::jesa(0.8, 2, cfg.moe.layers);
    let queue = QueueConfig::for_system(cfg.moe.experts, 1.0);
    let opts = ServeOptions {
        workers: 1,
        ..ServeOptions::new(policy, queue)
    };
    let traffic = TrafficConfig {
        queries,
        domains: 4,
        tokens_per_query: 2,
        seed: 1234,
        ..TrafficConfig::poisson(10.0, queries)
    };
    (cfg, opts, traffic)
}

#[test]
fn multi_round_latencies_match_discrete_event_timelines() {
    let (cfg, mut opts, traffic) = setup(120);
    opts.record_timelines = true;
    let engine = ServeEngine::new(&cfg, opts);
    let report = engine.run(&traffic);

    assert!(report.rounds > 1, "needs a multi-round run");
    assert_eq!(report.timelines.len(), report.rounds);
    for (round, timelines) in report.rounds_log.iter().zip(report.timelines.iter()) {
        // The engine's reported round latency is exactly the sum of the
        // per-layer discrete-event timelines.
        assert_eq!(timelines.len(), cfg.moe.layers);
        let recomputed: f64 = timelines.iter().map(|t| t.round_latency_s).sum();
        assert!(
            (round.latency_s - recomputed).abs() <= 1e-12,
            "round latency {} != timeline sum {recomputed}",
            round.latency_s
        );
        // critical_path terminates every layer's timeline at its latency
        // and is causally ordered.
        for tl in timelines {
            let path = tl.critical_path();
            if tl.round_latency_s > 0.0 {
                assert!(!path.is_empty());
                assert!(
                    (path.last().unwrap().time() - tl.round_latency_s).abs() <= 1e-12,
                    "critical path must end at the round latency"
                );
            }
            for w in path.windows(2) {
                assert!(w[0].time() <= w[1].time() + 1e-12, "path not causal");
            }
            // A backward delivery on the path must be preceded by its
            // expert's compute completion.
            for e in &path {
                if let Event::BackwardDone { from, at_s, .. } = e {
                    let compute = tl.events.iter().find_map(|x| match x {
                        Event::ComputeDone { expert, at_s } if expert == from => Some(*at_s),
                        _ => None,
                    });
                    let compute = compute.expect("backward without compute");
                    assert!(*at_s >= compute - 1e-12);
                }
            }
        }
    }

    // Per-query accounting agrees with the round it rode in: completion
    // time = round start + round latency.
    for c in &report.completions {
        let round = report
            .rounds_log
            .iter()
            .find(|r| (r.start_s - c.start_s).abs() <= 1e-12)
            .expect("every completion maps to a logged round");
        assert!(
            (c.done_s - (round.start_s + round.latency_s)).abs() <= 1e-12,
            "completion time disagrees with its round's timeline"
        );
        assert!((c.latency_s() - (c.done_s - c.arrival_s)).abs() <= 1e-15);
    }
}

#[test]
fn conservation_and_reported_statistics() {
    let (cfg, opts, traffic) = setup(300);
    let engine = ServeEngine::new(&cfg, opts);
    let report = engine.run(&traffic);

    assert_eq!(report.generated, 300);
    assert_eq!(report.completed + report.shed(), report.generated);
    assert_eq!(report.completed, report.completions.len());
    assert_eq!(
        report.rounds_log.iter().map(|r| r.queries).sum::<usize>(),
        report.completed
    );
    assert!(report.throughput_qps() > 0.0);
    assert!(report.latency_p50_s() > 0.0);
    assert!(report.latency_p99_s() >= report.latency_p50_s());
    assert!(report.energy.total_j() > 0.0);
    assert!(report.tokens > 0);
    // The render covers the acceptance-criteria numbers.
    let text = report.render();
    for needle in ["throughput", "p50", "p99", "shed", "cache", "energy"] {
        assert!(text.contains(needle), "render lacks {needle}: {text}");
    }
}

#[test]
fn cache_hits_nonzero_on_template_workload_and_identical_rerun() {
    let (cfg, opts, traffic) = setup(300);
    let a = ServeEngine::new(&cfg, opts.clone()).run(&traffic);
    assert!(
        a.cache.hits > 0,
        "template workload must produce cache hits: {:?}",
        a.cache
    );
    // Determinism end-to-end (cache included): identical reruns agree to
    // the bit on every reported number.
    let b = ServeEngine::new(&cfg, opts).run(&traffic);
    assert_eq!(a.cache.hits, b.cache.hits);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.energy.total_j().to_bits(), b.energy.total_j().to_bits());
    assert_eq!(a.completions.len(), b.completions.len());
    for (x, y) in a.completions.iter().zip(b.completions.iter()) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.done_s.to_bits(), y.done_s.to_bits());
    }
}

#[test]
fn bursty_and_diurnal_streams_serve_end_to_end() {
    for process in [
        ArrivalProcess::Mmpp {
            low_qps: 3.0,
            high_qps: 30.0,
            mean_dwell_s: 1.0,
        },
        ArrivalProcess::Diurnal {
            mean_qps: 10.0,
            peak_to_trough: 4.0,
            period_s: 10.0,
        },
    ] {
        let (cfg, opts, mut traffic) = setup(200);
        traffic.process = process;
        let report = ServeEngine::new(&cfg, opts).run(&traffic);
        assert_eq!(report.completed + report.shed(), report.generated);
        assert!(report.completed > 0, "stream must make progress");
    }
}

#[test]
fn quantization_step_trades_hit_rate() {
    // A much finer channel grid must not increase the hit rate.
    let (cfg, coarse_opts, traffic) = setup(300);
    let mut fine_opts = coarse_opts.clone();
    fine_opts.quant = QuantizerConfig {
        log2_step: 0.05,
        gate_levels: 4096,
    };
    let coarse = ServeEngine::new(&cfg, coarse_opts).run(&traffic);
    let fine = ServeEngine::new(&cfg, fine_opts).run(&traffic);
    assert!(
        fine.cache.hits <= coarse.cache.hits,
        "finer quantization ({}) must not out-hit coarser ({})",
        fine.cache.hits,
        coarse.cache.hits
    );
}

#[test]
fn engine_rejects_mismatched_policy_width() {
    let (cfg, opts, _) = setup(10);
    let bad = ServeOptions {
        policy: ServePolicy::jesa(0.8, 2, cfg.moe.layers + 1),
        ..opts
    };
    let result = std::panic::catch_unwind(|| ServeEngine::new(&cfg, bad));
    assert!(result.is_err(), "layer-width mismatch must be rejected");
}

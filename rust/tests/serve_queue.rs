//! Queue-level coverage under bursty (MMPP) arrivals: shed ordering and
//! deadline-trigger batch formation. The engine tests cover these
//! mechanics only end-to-end; here the admission queue is driven
//! directly by a miniature batch-former loop that mirrors the engine's
//! admission/trigger semantics, so each queue behavior is observable in
//! isolation.

use dmoe::serve::{
    AdmissionQueue, Arrival, ArrivalProcess, QueueConfig, ShedReason, TrafficConfig,
    TrafficGenerator,
};

fn mmpp_arrivals(low: f64, high: f64, dwell: f64, queries: usize) -> Vec<Arrival> {
    let cfg = TrafficConfig {
        process: ArrivalProcess::Mmpp {
            low_qps: low,
            high_qps: high,
            mean_dwell_s: dwell,
        },
        queries,
        tokens_per_query: 1,
        seed: 0xB1_57,
        ..TrafficConfig::poisson(1.0, queries)
    };
    TrafficGenerator::new(cfg, 4, 2).generate()
}

/// One formed batch, as the mini-driver saw it.
struct Formed {
    start_s: f64,
    ids: Vec<u64>,
    /// The size trigger was NOT met when the batch formed (deadline- or
    /// drain-triggered partial batch).
    partial: bool,
}

/// Drive the queue exactly like the serving engine does (admit every
/// arrival landing at or before the round's would-be start; form on the
/// size or deadline trigger; shed expired queries at round start), with
/// a fixed per-round service time standing in for the solver.
fn drive(queue: &mut AdmissionQueue, arrivals: Vec<Arrival>, service_s: f64) -> Vec<Formed> {
    let mut formed = Vec::new();
    let mut free_at = 0.0f64;
    let mut stream = arrivals.into_iter().peekable();
    while stream.peek().is_some() || !queue.is_empty() {
        if queue.is_empty() {
            queue.push(stream.next().expect("stream non-empty"));
            continue;
        }
        let trigger = queue.trigger_time_s().expect("queue non-empty");
        let start_if_now = trigger.max(free_at);
        if let Some(next) = stream.peek() {
            if next.at_s <= start_if_now {
                queue.push(stream.next().expect("peeked"));
                continue;
            }
        }
        let partial = !queue.batch_ready();
        let formed_at = if partial && stream.peek().is_none() {
            queue.newest_arrival_s().expect("queue non-empty")
        } else {
            trigger
        };
        let start = formed_at.max(free_at);
        queue.shed_expired(start);
        if queue.is_empty() {
            continue;
        }
        let batch = queue.take_batch();
        free_at = start + service_s;
        formed.push(Formed {
            start_s: start,
            ids: batch.iter().map(|a| a.query.id).collect(),
            partial,
        });
    }
    formed
}

fn queue(capacity: usize, batch: usize, max_wait: f64, deadline: f64) -> AdmissionQueue {
    AdmissionQueue::new(QueueConfig {
        capacity,
        batch_queries: batch,
        max_wait_s: max_wait,
        deadline_s: deadline,
    })
}

#[test]
fn bursty_stream_exercises_both_formation_triggers() {
    // Low state ≈ 1 q/s (inter-arrival ≫ max_wait 0.5 s → deadline
    // trigger forms partial batches); high state ≈ 60 q/s (the size
    // trigger fills batches of 4).
    let arrivals = mmpp_arrivals(1.0, 60.0, 3.0, 2000);
    let mut q = queue(64, 4, 0.5, f64::INFINITY);
    let formed = drive(&mut q, arrivals, 0.01);
    let served: usize = formed.iter().map(|f| f.ids.len()).sum();
    assert_eq!(served, 2000, "infinite deadline must serve every query");
    let partial = formed.iter().filter(|f| f.partial).count();
    let full = formed.iter().filter(|f| f.ids.len() == 4).count();
    assert!(
        partial > 5,
        "lulls must fire the deadline trigger (partial batches: {partial})"
    );
    assert!(
        full > 10,
        "bursts must fire the size trigger (full batches: {full})"
    );
    for f in &formed {
        assert!(f.ids.len() <= 4, "batch overflow: {}", f.ids.len());
        assert!(!f.ids.is_empty());
    }
    // Rounds never overlap and never start before their members arrive.
    for w in formed.windows(2) {
        assert!(w[1].start_s >= w[0].start_s + 0.01 - 1e-12, "rounds overlap");
    }
}

#[test]
fn batches_stay_fifo_under_bursts() {
    let arrivals = mmpp_arrivals(2.0, 80.0, 2.0, 1500);
    let mut q = queue(64, 4, 0.5, f64::INFINITY);
    let formed = drive(&mut q, arrivals, 0.02);
    // Ids were assigned in arrival order, so FIFO service means every
    // batch is ascending and batches never interleave.
    let mut last = 0u64;
    for f in &formed {
        for &id in &f.ids {
            assert!(
                id >= last || last == 0,
                "FIFO violated: id {id} after {last}"
            );
            last = id.max(last);
        }
        let mut sorted = f.ids.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, f.ids, "batch not in arrival order");
    }
}

#[test]
fn deadline_sheds_come_out_oldest_first() {
    // A service time far above the deadline piles the queue up and
    // forces deadline shedding at round starts.
    let arrivals = mmpp_arrivals(5.0, 100.0, 1.0, 1200);
    let mut q = queue(1024, 4, 0.2, 0.5);
    let formed = drive(&mut q, arrivals, 1.0);
    let (shed_full, shed_deadline) = q.shed_counts();
    assert_eq!(shed_full, 0, "capacity 1024 must never overflow here");
    assert!(shed_deadline > 50, "overload must shed ({shed_deadline})");
    let served: usize = formed.iter().map(|f| f.ids.len()).sum();
    assert_eq!(served + shed_deadline, 1200, "conservation");
    // Every shed is a deadline shed, and — queries having been admitted
    // in arrival order — the shed log is oldest-first throughout.
    let ids: Vec<u64> = q
        .shed_log()
        .iter()
        .map(|&(id, reason)| {
            assert_eq!(reason, ShedReason::DeadlineExceeded);
            id
        })
        .collect();
    for w in ids.windows(2) {
        assert!(w[0] < w[1], "deadline sheds out of order: {} then {}", w[0], w[1]);
    }
}

#[test]
fn capacity_sheds_exactly_the_overflow_under_bursts() {
    // A tiny queue in front of a slow server: bursts overflow capacity,
    // and the queue never holds more than its bound.
    let arrivals = mmpp_arrivals(5.0, 150.0, 1.0, 800);
    let mut q = queue(6, 3, 0.2, f64::INFINITY);
    let total = arrivals.len();
    let formed = drive(&mut q, arrivals, 0.5);
    let (shed_full, shed_deadline) = q.shed_counts();
    assert_eq!(shed_deadline, 0, "infinite deadline never sheds by age");
    assert!(shed_full > 0, "bursts must overflow a 6-slot queue");
    let served: usize = formed.iter().map(|f| f.ids.len()).sum();
    assert_eq!(served + shed_full, total, "conservation");
    for (id, reason) in q.shed_log() {
        assert_eq!(*reason, ShedReason::QueueFull, "query {id}");
    }
}

//! Sweep layer coverage: spec round-trips, deterministic expansion and
//! axis application, same-spec bit-identical manifests, the
//! PASS/CHANGED verdict paths of `--check`, and deep sweep-root
//! verification — the in-tree half of the ci.sh sweep gate.

use dmoe::scenario::PolicyKind;
use dmoe::sweep::{
    check_manifests, run_sweep, verify_sweep_root, SweepSpec, Verdict, SWEEP_SCHEMA_VERSION,
};
use dmoe::util::json::Json;
use std::path::PathBuf;

/// A 4-point grid over {des, topk:2} × two seeds, small enough to run
/// in-process. `workers: 1` pins the per-layer pool so informational
/// fields are deterministic too.
fn tiny_spec(name: &str, seeds: &[u64]) -> SweepSpec {
    let text = format!(
        r#"{{
  "sweep_schema_version": 1,
  "name": "{name}",
  "base": "paper-baseline",
  "queries": 100,
  "workers": 1,
  "axes": {{
    "selector": ["des", "topk:2"],
    "seed": {seeds:?}
  }}
}}"#
    );
    SweepSpec::from_json_str(&text).unwrap()
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dmoe-sweep-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn point_digests(manifest: &Json) -> Vec<(String, String, String)> {
    manifest
        .get("points")
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| {
            (
                p.get("name").as_str().unwrap().to_string(),
                p.get("scenario_digest").as_str().unwrap().to_string(),
                p.get("report_digest").as_str().unwrap().to_string(),
            )
        })
        .collect()
}

// -- spec document ----------------------------------------------------------

#[test]
fn spec_json_round_trips_bit_identically() {
    let spec = tiny_spec("round-trip", &[7, 9]);
    let text = spec.to_json().to_string_pretty();
    let back = SweepSpec::from_json_str(&text).unwrap();
    assert_eq!(back, spec);
    assert_eq!(back.to_json().to_string_pretty(), text);
    assert_eq!(back.digest(), spec.digest());
    assert_eq!(spec.schema_version, SWEEP_SCHEMA_VERSION);
}

#[test]
fn spec_rejects_bad_fields_with_field_paths() {
    let unknown = r#"{"name": "x", "base": "paper-baseline", "axis": {}}"#;
    let err = format!("{:#}", SweepSpec::from_json_str(unknown).unwrap_err());
    assert!(err.contains("sweep") && err.contains("axis"), "{err}");

    let bad_gamma = r#"{"name": "x", "base": "paper-baseline",
        "axes": {"gamma0": [1.5]}}"#;
    let err = format!("{:#}", SweepSpec::from_json_str(bad_gamma).unwrap_err());
    assert!(err.contains("sweep.axes.gamma0[0]"), "{err}");

    let bad_selector = r#"{"name": "x", "base": "paper-baseline",
        "axes": {"selector": ["warp-drive"]}}"#;
    let err = format!("{:#}", SweepSpec::from_json_str(bad_selector).unwrap_err());
    assert!(err.contains("sweep.axes.selector[0]"), "{err}");
}

// -- deterministic expansion ------------------------------------------------

#[test]
fn expansion_is_deterministic_and_applies_axes() {
    let spec = tiny_spec("expand", &[11, 12]);
    let points = spec.expand().unwrap();
    assert_eq!(points.len(), 4);
    // Fixed nesting order: selector outer, seed inner.
    let names: Vec<&str> = points.iter().map(|p| p.name.as_str()).collect();
    assert_eq!(names, ["p000", "p001", "p002", "p003"]);
    assert_eq!(points[0].scenario.name, "expand-p000");
    assert_eq!(points[0].scenario.system.workload.seed, 11);
    assert_eq!(points[1].scenario.system.workload.seed, 12);
    assert_eq!(points[0].scenario.policy.selector.unwrap().name(), "des");
    assert_eq!(points[2].scenario.policy.selector.unwrap().name(), "topk:2");
    for p in &points {
        assert_eq!(p.scenario.traffic.queries, 100);
        assert_eq!(p.scenario.workers, Some(1));
        assert_eq!(
            p.labels.iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>(),
            ["selector", "seed"]
        );
    }
    // Expansion is pure: a second expansion is identical.
    assert_eq!(spec.expand().unwrap(), points);
}

#[test]
fn cells_and_gamma0_axes_shape_the_point_scenarios() {
    let text = r#"{
  "name": "shape",
  "base": "paper-baseline",
  "queries": 50,
  "lane_workers": 0,
  "axes": {"cells": [1, 4], "gamma0": [0.5, 0.9]}
}"#;
    let spec = SweepSpec::from_json_str(text).unwrap();
    let points = spec.expand().unwrap();
    assert_eq!(points.len(), 4);
    // cells=1 collapses to the serve engine; cells=4 shapes a fleet
    // with the spec-level lane_workers override applied.
    assert!(points[0].scenario.fleet.is_none());
    let fleet = points[2].scenario.fleet.as_ref().unwrap();
    assert_eq!(fleet.cells, 4);
    assert_eq!(fleet.lane_workers, Some(0));
    for (i, want) in [(0, 0.5), (1, 0.9), (2, 0.5), (3, 0.9)] {
        match points[i].scenario.policy.kind {
            PolicyKind::Jesa { gamma0, .. } => assert_eq!(gamma0, want),
            _ => panic!("paper-baseline is jesa-shaped"),
        }
    }
}

#[test]
fn gamma0_axis_requires_an_importance_factor_policy() {
    let text = r#"{
  "name": "bad-gamma-base",
  "base": "paper-baseline",
  "axes": {"selector": ["des"], "gamma0": [0.5]}
}"#;
    // Swap the base policy to topk, which has no gamma0 knob.
    let mut spec = SweepSpec::from_json_str(text).unwrap();
    let mut base = spec.base_scenario().unwrap();
    base.policy = dmoe::scenario::PolicySpec::topk(2);
    spec.base = dmoe::sweep::BaseRef::Inline(Box::new(base));
    let err = format!("{:#}", spec.expand().unwrap_err());
    assert!(err.contains("gamma0"), "{err}");
}

#[test]
fn gamma0_axis_rejects_an_adaptive_control_base() {
    let text = r#"{
  "name": "bad-gamma-control",
  "base": "paper-baseline",
  "axes": {"gamma0": [0.5]}
}"#;
    // Enable adaptive γ control on the base: the controller owns γ at
    // runtime, so sweeping gamma0 under it must be rejected.
    let mut spec = SweepSpec::from_json_str(text).unwrap();
    let mut base = spec.base_scenario().unwrap();
    base.control = Some(dmoe::control::ControlSpec {
        gamma_min: 0.5,
        ..Default::default()
    });
    spec.base = dmoe::sweep::BaseRef::Inline(Box::new(base));
    let err = format!("{:#}", spec.expand().unwrap_err());
    assert!(
        err.contains("sweep.axes.gamma0") && err.contains("control"),
        "{err}"
    );
}

// -- sweep runs: bit-identical manifests, verification, verdicts ------------

#[test]
fn same_spec_runs_to_bit_identical_digests_and_verifies() {
    let spec = tiny_spec("determinism", &[11, 12]);
    let (root_a, root_b) = (scratch("det-a"), scratch("det-b"));
    let a = run_sweep(&spec, &root_a, 2).unwrap();
    let b = run_sweep(&spec, &root_b, 2).unwrap();

    // Same spec, two runs: identical per-point digests and spec
    // checksum (wall-clock manifest fields are exempt by contract).
    assert_eq!(point_digests(&a), point_digests(&b));
    assert_eq!(
        a.get("spec_fnv1a").as_str().unwrap(),
        b.get("spec_fnv1a").as_str().unwrap()
    );
    // All four points are distinct scenarios with distinct digests.
    let digests = point_digests(&a);
    assert_eq!(digests.len(), 4);
    for i in 0..digests.len() {
        for j in (i + 1)..digests.len() {
            assert_ne!(digests[i].1, digests[j].1, "{i} vs {j}");
        }
    }

    // Deep on-disk verification: every per-point artifact plus the
    // sweep-level digest cross-check.
    let (points, name) = verify_sweep_root(&root_a).unwrap();
    assert_eq!((points, name.as_str()), (4, "determinism"));

    // A diff against itself is an all-PASS report.
    let report = check_manifests(&a, &b);
    assert_eq!(report.points.len(), 4);
    assert_eq!(report.worst(), Verdict::Pass);

    // Tampering with a point artifact breaks deep verification with a
    // diagnostic naming the file.
    let victim = root_a.join("points/p001/report.json");
    let text = std::fs::read_to_string(&victim).unwrap();
    std::fs::write(&victim, text + " ").unwrap();
    let err = format!("{:#}", verify_sweep_root(&root_a).unwrap_err());
    assert!(err.contains("p001"), "must name the point: {err}");
    assert!(err.contains("report.json"), "must name the file: {err}");

    let _ = std::fs::remove_dir_all(&root_a);
    let _ = std::fs::remove_dir_all(&root_b);
}

// -- chaos axis --------------------------------------------------------------

#[test]
fn chaos_axis_expands_and_labels_points() {
    let text = r#"{
  "name": "chaos-grid",
  "base": "paper-baseline",
  "queries": 50,
  "axes": {
    "chaos": [
      {"seed": 5, "expert_outages": [
        {"expert": 1, "down_at": {"rounds": 2}, "up_at": {"rounds": 9}}]},
      {"seed": 6, "link": {"fail_prob": 0.2, "max_retries": 1}}
    ],
    "seed": [11, 12]
  }
}"#;
    let spec = SweepSpec::from_json_str(text).unwrap();
    // The chaos axis round-trips through the spec document.
    let back = SweepSpec::from_json_str(&spec.to_json().to_string_pretty()).unwrap();
    assert_eq!(back, spec);

    let points = spec.expand().unwrap();
    assert_eq!(points.len(), 4);
    // Chaos outer, seed inner; labels carry the compact chaos tag.
    for (i, p) in points.iter().enumerate() {
        let chaos = p.scenario.chaos.as_ref().expect("chaos axis must apply");
        let label = p
            .labels
            .iter()
            .find(|(k, _)| k.as_str() == "chaos")
            .map(|(_, v)| v.as_str())
            .unwrap();
        if i < 2 {
            assert_eq!(chaos.expert_outages.len(), 1);
            assert_eq!(label, "o1l0c0s5");
        } else {
            assert!(chaos.link.is_some());
            assert_eq!(label, "o0l1c0s6");
        }
    }
}

#[test]
fn perturbed_chaos_seed_reports_changed() {
    let spec_text = |seed: u64| {
        format!(
            r#"{{
  "name": "chaos-check",
  "base": "paper-baseline",
  "queries": 60,
  "workers": 1,
  "axes": {{"chaos": [{{"seed": {seed}, "link": {{"fail_prob": 0.2, "max_retries": 1}}}}]}}
}}"#
        )
    };
    let baseline_spec = SweepSpec::from_json_str(&spec_text(5)).unwrap();
    let perturbed_spec = SweepSpec::from_json_str(&spec_text(6)).unwrap();
    let (root_a, root_b) = (scratch("chaos-a"), scratch("chaos-b"));
    let baseline = run_sweep(&baseline_spec, &root_a, 1).unwrap();
    let fresh = run_sweep(&perturbed_spec, &root_b, 1).unwrap();

    // Only the chaos seed moved, so the scenario digest moves and the
    // cross-run comparison must flag CHANGED — chaos is part of the
    // reviewed document, never an ambient knob.
    let report = check_manifests(&baseline, &fresh);
    assert_eq!(report.worst(), Verdict::Changed);
    assert_ne!(point_digests(&baseline)[0].1, point_digests(&fresh)[0].1);

    let _ = std::fs::remove_dir_all(&root_a);
    let _ = std::fs::remove_dir_all(&root_b);
}

#[test]
fn perturbed_seed_axis_reports_changed_with_digests_named() {
    let baseline_spec = tiny_spec("check", &[11, 12]);
    let perturbed_spec = tiny_spec("check", &[13, 14]);
    let (root_a, root_b) = (scratch("chk-a"), scratch("chk-b"));
    let baseline = run_sweep(&baseline_spec, &root_a, 2).unwrap();
    let fresh = run_sweep(&perturbed_spec, &root_b, 2).unwrap();

    let report = check_manifests(&baseline, &fresh);
    assert_eq!(report.worst(), Verdict::Changed);
    let baseline_digests = point_digests(&baseline);
    let fresh_digests = point_digests(&fresh);
    for (i, p) in report.points.iter().enumerate() {
        assert_eq!(p.verdict, Verdict::Changed, "{}", p.name);
        // The verdict line names both scenario digests.
        assert!(p.detail.contains(&baseline_digests[i].1), "{}", p.detail);
        assert!(p.detail.contains(&fresh_digests[i].1), "{}", p.detail);
    }

    let _ = std::fs::remove_dir_all(&root_a);
    let _ = std::fs::remove_dir_all(&root_b);
}

//! End-to-end coverage of the telemetry layer: sketch-vs-exact accuracy
//! on real engine runs, observer-vs-report agreement on the preset
//! library, the O(1) default path (no per-query records, same digest),
//! shard-order-invariant merges, and fleet digest stability with a
//! `TelemetryObserver` attached in both execution modes.

use dmoe::fleet::{MobilityConfig, RoutePolicy};
use dmoe::scenario::{
    self, FleetSpec, PrepareOptions, RateSpec, RunReport, Scenario, TrafficSpec,
};
use dmoe::telemetry::{verify_artifact, write_run_artifact, LatencyStats, TelemetryObserver};
use dmoe::util::stats;
use dmoe::SystemConfig;
use std::path::PathBuf;

const EXACT: PrepareOptions = PrepareOptions {
    record_completions: true,
};

fn small_preset(name: &str, queries: usize) -> Scenario {
    let mut s = Scenario::preset(name).unwrap();
    s.traffic.queries = queries;
    s
}

/// A small fleet scenario with a parametric lane count, for the
/// parallel-vs-sequential digest checks below.
fn two_cell_fleet(queries: usize, lane_workers: usize) -> Scenario {
    let mut cfg = SystemConfig::tiny();
    cfg.workload.seed = 4242;
    Scenario::builder("telemetry-fleet")
        .system(cfg)
        .traffic(TrafficSpec {
            queries,
            domains: 4,
            tokens_per_query: 2,
            rate: RateSpec::Qps(15.0),
            ..TrafficSpec::default()
        })
        .workers(1)
        .fleet(FleetSpec {
            cells: 2,
            route: RoutePolicy::RoundRobin,
            mobility: MobilityConfig {
                users: 24,
                ..MobilityConfig::default()
            },
            lane_workers: Some(lane_workers),
            ..FleetSpec::default()
        })
        .build()
        .unwrap()
}

// -- sketch accuracy against exact per-query records ------------------------

#[test]
fn sketch_quantiles_track_exact_latencies_on_a_real_run() {
    let s = small_preset("paper-baseline", 400);
    let report = scenario::prepare_opts(&s, &EXACT).unwrap().run();
    let exact = report.exact_latencies_sorted();
    assert!(!exact.is_empty(), "exact mode must keep completion records");
    let stats_ = report.latency();
    assert_eq!(stats_.count(), exact.len() as u64);
    let alpha = stats_.sketch().alpha();
    for q in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
        let want = stats::nearest_rank(&exact, q);
        let got = stats_.quantile(q);
        assert!(
            (got - want).abs() <= alpha * want.abs() + 1e-12,
            "p{q}: sketch {got} vs exact {want} beyond alpha {alpha}"
        );
    }
    // The exact sum also survives streaming: mean agrees to fp error.
    let mean = exact.iter().sum::<f64>() / exact.len() as f64;
    assert!((stats_.mean_s() - mean).abs() < 1e-9);
}

// -- observer aggregates equal the report's ---------------------------------

#[test]
fn observer_stats_equal_report_stats_on_presets() {
    for name in ["paper-baseline", "urban-macro-jsq"] {
        let s = small_preset(name, 300);
        let prepared = scenario::prepare_opts(&s, &EXACT).unwrap();
        let mut tel = TelemetryObserver::new();
        tel.set_layers(s.system.moe.layers);
        let report = prepared.run_observed(&mut tel);

        assert_eq!(tel.rounds, report.rounds() as u64, "{name}: rounds");
        assert_eq!(
            tel.completions,
            report.completed() as u64,
            "{name}: completions"
        );
        assert_eq!(tel.sheds, report.shed() as u64, "{name}: sheds");
        assert_eq!(
            tel.query_latency.count(),
            report.latency().count(),
            "{name}: latency sample count"
        );
        // The observer's sketch is built from the same samples as the
        // report's (integer bucket counts), so quantiles are bit-equal.
        for q in [50.0, 95.0, 99.0] {
            assert_eq!(
                tel.query_latency.quantile(q).to_bits(),
                report.latency().quantile(q).to_bits(),
                "{name}: p{q} observer vs report"
            );
        }
        if let RunReport::Fleet(r) = &report {
            assert_eq!(tel.handovers, r.handovers as u64, "{name}: handovers");
            assert!(
                !tel.per_cell().is_empty() && tel.per_cell().len() <= r.cells.len(),
                "{name}: per-cell slices"
            );
            let cell_completions: u64 =
                tel.per_cell().values().map(|c| c.completions).sum();
            assert_eq!(cell_completions, tel.completions, "{name}: cell partition");
        }
        let cache = tel.cache.expect("final cache stats must arrive");
        assert_eq!(cache.hits, report.cache().hits, "{name}: cache hits");
    }
}

// -- the O(1) default path --------------------------------------------------

#[test]
fn default_path_streams_with_no_per_query_records_and_same_digest() {
    let s = small_preset("paper-baseline", 300);
    let streaming = scenario::prepare(&s).unwrap().run();
    let exact = scenario::prepare_opts(&s, &EXACT).unwrap().run();

    match &streaming {
        RunReport::Serve(r) => {
            assert!(
                r.completions.is_empty(),
                "default path must not store per-query records"
            );
            assert!(r.completed > 0);
            assert_eq!(r.latency.count(), r.completed as u64);
        }
        RunReport::Fleet(_) => panic!("paper-baseline is serve-shaped"),
    }
    assert!(streaming.exact_latencies_sorted().is_empty());
    assert!(!exact.exact_latencies_sorted().is_empty());
    // Recording per-query records is observability only: digests and
    // streamed latency stats are identical either way.
    assert_eq!(streaming.digest(), exact.digest());
    for q in [50.0, 95.0, 99.0] {
        assert_eq!(
            streaming.latency().quantile(q).to_bits(),
            exact.latency().quantile(q).to_bits()
        );
    }
}

#[test]
fn fleet_default_path_streams_with_no_per_query_records() {
    let s = two_cell_fleet(300, 0);
    let streaming = scenario::prepare(&s).unwrap().run();
    let exact = scenario::prepare_opts(&s, &EXACT).unwrap().run();
    match &streaming {
        RunReport::Fleet(r) => {
            assert!(r.completions.is_empty());
            assert!(r.completed > 0);
            assert_eq!(r.latency.count(), r.completed as u64);
        }
        RunReport::Serve(_) => panic!("fleet-shaped scenario ran the serve engine"),
    }
    assert_eq!(streaming.digest(), exact.digest());
}

// -- merge properties -------------------------------------------------------

#[test]
fn latency_stats_merge_is_shard_order_invariant() {
    // Three shards with disjoint, differently-shaped samples.
    let mut shards = vec![
        LatencyStats::default(),
        LatencyStats::default(),
        LatencyStats::default(),
    ];
    for i in 0..3000u32 {
        let x = match i % 3 {
            0 => 1e-4 * (1.0 + i as f64),
            1 => 0.5 + (i as f64) * 1e-6,
            _ => 10.0 / (1.0 + i as f64),
        };
        shards[(i % 3) as usize].record(x);
    }
    let mut fwd = LatencyStats::default();
    for s in &shards {
        fwd.merge(s);
    }
    let mut rev = LatencyStats::default();
    for s in shards.iter().rev() {
        rev.merge(s);
    }
    assert_eq!(fwd.count(), rev.count());
    for q in [0.0, 25.0, 50.0, 75.0, 95.0, 99.0, 100.0] {
        assert_eq!(fwd.quantile(q).to_bits(), rev.quantile(q).to_bits());
    }
}

// -- fleet digest stability with telemetry attached -------------------------

#[test]
fn fleet_parallel_vs_sequential_digest_survives_telemetry_observer() {
    let seq = two_cell_fleet(400, 0);
    let par = two_cell_fleet(400, 4);

    let plain = scenario::run(&seq).unwrap().digest();
    let mut digests = Vec::new();
    for s in [&seq, &par] {
        let mut tel = TelemetryObserver::new();
        tel.set_layers(s.system.moe.layers);
        let report = scenario::prepare(s).unwrap().run_observed(&mut tel);
        assert!(tel.rounds > 0, "observer must see the replayed rounds");
        digests.push(report.digest());
    }
    assert_eq!(
        digests[0], digests[1],
        "sequential vs lane-parallel digest must match with telemetry attached"
    );
    assert_eq!(
        digests[0], plain,
        "telemetry observation must be passive wrt the digest"
    );
}

// -- artifact-verifier failure modes ----------------------------------------
//
// Every corruption must fail `verify_artifact` with a diagnostic that
// names the offending file, so `dmoe artifact` output is actionable.

/// Write a small real run artifact into a scratch dir and return it.
fn artifact_fixture(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "dmoe-telemetry-artifact-{}-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let s = small_preset("paper-baseline", 200);
    let prepared = scenario::prepare(&s).unwrap();
    let mut tel = TelemetryObserver::new();
    tel.set_layers(s.system.moe.layers);
    let report = prepared.run_observed(&mut tel);
    write_run_artifact(&dir, &prepared.scenario, &report, &tel).unwrap();
    verify_artifact(&dir).expect("fresh artifact must verify");
    dir
}

/// Swap the first 16-hex-digit value of `"key": "0x…"` in `text` for a
/// different constant (guaranteed to differ from the original).
fn swap_hex_value(text: &str, key: &str) -> (String, &'static str) {
    let marker = format!("\"{key}\": \"0x");
    let idx = text.find(&marker).expect("hex field present");
    let start = idx + marker.len();
    let old = &text[start..start + 16];
    let new = if old == "0123456789abcdef" {
        "fedcba9876543210"
    } else {
        "0123456789abcdef"
    };
    let mut out = text.to_string();
    out.replace_range(start..start + 16, new);
    (out, new)
}

#[test]
fn verifier_catches_corrupted_entry_bytes() {
    let dir = artifact_fixture("corrupt-entry");
    let path = dir.join("report.json");
    let text = std::fs::read_to_string(&path).unwrap();
    // Flip one digit in place: same byte length, different content, so
    // only the FNV checksum can catch it.
    let flipped: String = {
        let mut done = false;
        text.chars()
            .map(|c| {
                if !done && c.is_ascii_digit() {
                    done = true;
                    char::from_digit((c.to_digit(10).unwrap() + 1) % 10, 10).unwrap()
                } else {
                    c
                }
            })
            .collect()
    };
    assert_ne!(flipped, text);
    std::fs::write(&path, flipped).unwrap();
    let err = format!("{:#}", verify_artifact(&dir).unwrap_err());
    assert!(err.contains("report.json"), "must name the file: {err}");
    assert!(err.contains("checksum mismatch"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn verifier_catches_edited_manifest_checksum() {
    let dir = artifact_fixture("edited-manifest");
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path).unwrap();
    // The first "fnv1a" entry belongs to report.json (files are sorted).
    let (edited, planted) = swap_hex_value(&text, "fnv1a");
    std::fs::write(&path, edited).unwrap();
    let err = format!("{:#}", verify_artifact(&dir).unwrap_err());
    assert!(err.contains("report.json"), "must name the file: {err}");
    assert!(err.contains("checksum mismatch"), "{err}");
    assert!(
        err.contains(&format!("0x{planted}")),
        "must name the bogus manifest checksum: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn verifier_catches_truncated_manifest() {
    let dir = artifact_fixture("truncated-manifest");
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    let err = format!("{:#}", verify_artifact(&dir).unwrap_err());
    assert!(
        err.contains("manifest.json"),
        "must name the file: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn verifier_catches_report_digest_mismatch() {
    let dir = artifact_fixture("digest-mismatch");
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path).unwrap();
    // Rewriting the manifest's report_digest leaves every per-file
    // checksum intact; only the report.json embedded-digest cross-check
    // can catch it. Patch the file-entry checksum for manifest
    // consistency is NOT needed: manifest.json is not self-checksummed.
    let (edited, planted) = swap_hex_value(&text, "report_digest");
    std::fs::write(&path, edited).unwrap();
    let err = format!("{:#}", verify_artifact(&dir).unwrap_err());
    assert!(err.contains("report digest mismatch"), "{err}");
    assert!(err.contains("report.json"), "must name the file: {err}");
    assert!(
        err.contains(&format!("0x{planted}")),
        "must name the planted digest: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

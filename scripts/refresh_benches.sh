#!/usr/bin/env bash
# Refresh the committed bench baselines (BENCH_des.json, BENCH_fleet.json,
# BENCH_serve.json) in *full* mode and leave them at the repo root, ready
# to commit. Run on a quiet machine — the numbers are wall-clock.
#
#   ./scripts/refresh_benches.sh
#
# ci.sh only *bootstraps* missing BENCH files (quick mode,
# DMOE_BENCH_FAST=1); deliberate refreshes after a perf PR go through
# this script so the committed baselines stay full-fidelity. Each bench
# stamps the scenario and git rev into its JSON, so commit these together
# with the change that moved the numbers.
set -euo pipefail
cd "$(dirname "$0")/.."

for b in des fleet serve; do
  echo "== cargo bench --bench $b =="
  cargo bench --bench "$b"
done

echo
echo "refreshed: $(ls BENCH_*.json | tr '\n' ' ')"
echo "review the deltas, then commit the BENCH_*.json files."
